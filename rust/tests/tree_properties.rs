//! Cross-module property tests over whole training runs: invariants
//! that must hold for *any* seed/configuration, checked on sampled
//! configurations (hand-rolled harness; no proptest in the vendored
//! set).

use oocgb::config::{ExecMode, SamplingMethod, TrainConfig};
use oocgb::coordinator::TrainSession;
use oocgb::data::synthetic::{self, ClassificationSpec};
use oocgb::util::prop::run_prop;

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.n_rounds = 3;
    cfg.max_depth = 3;
    cfg.max_bin = 16;
    cfg.learning_rate = 0.4;
    cfg
}

/// Leaf covers (hessian sums) of every tree must sum to the training-row
/// hessian mass that round (all rows when unsampled, logistic h ≤ 0.25).
#[test]
fn prop_leaf_cover_conservation() {
    run_prop("leaf cover conservation", 6, |g| {
        let rows = g.usize_in(300..1200);
        let data = synthetic::higgs_like(rows, g.u64());
        let cfg = base_cfg();
        let out = TrainSession::from_memory(data, cfg).unwrap().train().unwrap();
        for tree in &out.model.trees {
            let leaf_cover: f64 =
                tree.nodes.iter().filter(|n| n.is_leaf()).map(|n| n.sum_hess).sum();
            let root_cover = tree.nodes[0].sum_hess;
            assert!(
                (leaf_cover - root_cover).abs() < 1e-3 * root_cover.max(1.0),
                "leaves {leaf_cover} vs root {root_cover}"
            );
            assert!(root_cover <= 0.25 * rows as f64 + 1e-6);
        }
    });
}

/// Tree structure sanity for arbitrary runs: children deeper by one,
/// interior gains positive, binned and raw prediction agree on the
/// training rows.
#[test]
fn prop_tree_structure_and_prediction_consistency() {
    run_prop("tree structure", 5, |g| {
        let spec = ClassificationSpec {
            n_rows: g.usize_in(200..800),
            n_cols: g.usize_in(3..10),
            n_informative: 3,
            n_redundant: 1,
            seed: g.u64(),
            ..Default::default()
        };
        let data = synthetic::make_classification(spec);
        let mut cfg = base_cfg();
        cfg.max_depth = g.usize_in(1..5);
        let out = TrainSession::from_memory(data.clone(), cfg)
            .unwrap()
            .train()
            .unwrap();
        for tree in &out.model.trees {
            for (i, n) in tree.nodes.iter().enumerate() {
                if n.is_leaf() {
                    continue;
                }
                assert!(n.gain > 0.0, "interior node {i} gain {}", n.gain);
                assert_eq!(tree.nodes[n.left].depth, n.depth + 1);
                assert_eq!(tree.nodes[n.right].depth, n.depth + 1);
                assert!(n.split_value.is_finite());
            }
        }
        // Model predictions are finite probabilities.
        let preds = out.model.predict(&data);
        assert!(preds.iter().all(|p| p.is_finite() && (0.0..=1.0).contains(p)));
    });
}

/// Training is deterministic: identical config + seed ⇒ identical model
/// (trees, eval history), for both in-core and out-of-core pipelines.
#[test]
fn prop_determinism_across_runs() {
    run_prop("determinism", 3, |g| {
        let seed = g.u64();
        let rows = g.usize_in(300..900);
        for mode in [ExecMode::CpuInCore, ExecMode::CpuOutOfCore] {
            let mut cfg = base_cfg();
            cfg.mode = mode;
            cfg.seed = seed;
            cfg.eval_fraction = 0.2;
            cfg.page_size_bytes = 4096;
            cfg.sampling_method = SamplingMethod::Mvs;
            cfg.subsample = 0.6;
            let a = TrainSession::from_memory(synthetic::higgs_like(rows, seed), cfg.clone())
                .unwrap()
                .train()
                .unwrap();
            let b = TrainSession::from_memory(synthetic::higgs_like(rows, seed), cfg)
                .unwrap()
                .train()
                .unwrap();
            assert_eq!(a.model.trees.len(), b.model.trees.len());
            for (ta, tb) in a.model.trees.iter().zip(&b.model.trees) {
                // Leaf split_value is NaN by convention, so PartialEq on
                // Node can't be used directly; the JSON dump is NaN-free.
                assert_eq!(
                    ta.to_json().to_json(),
                    tb.to_json().to_json(),
                    "trees diverged in {}",
                    mode.name()
                );
            }
            assert_eq!(a.eval_history, b.eval_history);
        }
    });
}

/// More boosting rounds never worsen *training-set* fit for the squared
/// objective without sampling (each tree minimizes the Taylor objective
/// on the training set).
#[test]
fn prop_training_loss_monotone_squared() {
    run_prop("training loss monotone", 3, |g| {
        let rows = g.usize_in(300..800);
        let mut page = oocgb::data::SparsePage::new(3);
        let mut labels = Vec::new();
        let mut rng = oocgb::util::rng::Rng::new(g.u64());
        for _ in 0..rows {
            let x: Vec<f32> = (0..3).map(|_| rng.next_f32()).collect();
            labels.push(x[0] * 2.0 - x[1]);
            page.push_dense_row(&x);
        }
        let data = oocgb::data::DMatrix::from_page(page, labels.clone()).unwrap();
        let mut cfg = base_cfg();
        cfg.objective = "reg:squarederror".into();
        cfg.n_rounds = 8;
        cfg.learning_rate = 0.3;
        let out = TrainSession::from_memory(data.clone(), cfg).unwrap().train().unwrap();
        // Evaluate RMSE on the training set after each prefix of trees.
        let mut prev = f64::INFINITY;
        for k in [1usize, 2, 4, 8] {
            let mut partial = out.model.clone();
            partial.trees.truncate(k);
            let preds = partial.predict(&data);
            let rmse: f64 = (preds
                .iter()
                .zip(&labels)
                .map(|(p, y)| ((p - y) as f64).powi(2))
                .sum::<f64>()
                / rows as f64)
                .sqrt();
            assert!(
                rmse <= prev + 1e-9,
                "training RMSE rose at k={k}: {prev} → {rmse}"
            );
            prev = rmse;
        }
    });
}

/// Feature importance concentrates on informative features: with 2
/// informative + several pure-noise columns, the noise share stays low.
#[test]
fn prop_importance_on_informative_features() {
    run_prop("importance", 3, |g| {
        let spec = ClassificationSpec {
            n_rows: 1500,
            n_cols: 10,
            n_informative: 2,
            n_redundant: 0,
            flip_y: 0.0,
            class_sep: 1.5,
            seed: g.u64(),
        };
        let data = synthetic::make_classification(spec);
        let mut cfg = base_cfg();
        cfg.n_rounds = 6;
        let out = TrainSession::from_memory(data, cfg).unwrap().train().unwrap();
        let imp = out.model.feature_importance();
        let informative: f64 = imp[..2].iter().sum();
        assert!(
            informative > 0.8,
            "informative features carry only {informative:.2} of the gain: {imp:?}"
        );
    });
}
