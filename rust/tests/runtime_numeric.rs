//! Integration: the runtime (PJRT executor or the default CPU stub)
//! executes the artifact entry points and the numbers match pure-Rust
//! oracles (which themselves mirror ref.py).
//!
//! The `xla` build requires `make artifacts` and skips gracefully when
//! artifacts/ is absent; the default stub build synthesizes its
//! manifest, so these tests always run under plain `cargo test`.

use std::path::{Path, PathBuf};

use oocgb::runtime::Runtime;
use oocgb::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let d = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("manifest.json").exists() || cfg!(not(feature = "xla")) {
        Some(d)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn gradients_match_host_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let b = *rt.grad_batches().first().unwrap();
    let mut rng = Rng::new(1);
    let preds: Vec<f32> = (0..b).map(|_| rng.normal() as f32 * 2.0).collect();
    let labels: Vec<f32> = (0..b).map(|_| rng.bernoulli(0.4) as i32 as f32).collect();

    let out = rt.gradients(&preds, &labels, b, "binary:logistic").unwrap();
    assert_eq!(out.len(), b * 2);
    for i in (0..b).step_by(97) {
        let p = 1.0 / (1.0 + (-preds[i] as f64).exp());
        let g = p - labels[i] as f64;
        let h = (p * (1.0 - p)).max(1e-16);
        assert!((out[i * 2] as f64 - g).abs() < 1e-5, "g row {i}");
        assert!((out[i * 2 + 1] as f64 - h).abs() < 1e-5, "h row {i}");
    }

    let out = rt.gradients(&preds, &labels, b, "reg:squarederror").unwrap();
    for i in (0..b).step_by(131) {
        assert!((out[i * 2] - (preds[i] - labels[i])).abs() < 1e-6);
        assert_eq!(out[i * 2 + 1], 1.0);
    }
}

#[test]
fn mvs_scores_match_host_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let b = *rt.grad_batches().first().unwrap();
    let mut rng = Rng::new(2);
    let grads: Vec<f32> = (0..b * 2).map(|_| rng.normal() as f32).collect();
    let lam = 0.7f32;
    let (scores, total) = rt.mvs_scores(&grads, lam, b).unwrap();
    assert_eq!(scores.len(), b);
    let mut want_total = 0.0f64;
    for i in 0..b {
        let (g, h) = (grads[i * 2] as f64, grads[i * 2 + 1] as f64);
        let want = (g * g + lam as f64 * h * h).sqrt();
        assert!((scores[i] as f64 - want).abs() < 1e-5, "row {i}");
        want_total += want;
    }
    assert!((total as f64 - want_total).abs() / want_total < 1e-4);
}

#[test]
fn histogram_matches_host_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let n_bins = 64usize;
    let batch = *rt.hist_batches(n_bins).first().unwrap();
    let f_tile = rt.hist_feature_tile(n_bins).unwrap();
    let slots = rt.hist_node_slots(n_bins).unwrap();

    let mut rng = Rng::new(3);
    let bins: Vec<i32> =
        (0..batch * f_tile).map(|_| rng.gen_range(n_bins as u64) as i32).collect();
    let mut grads: Vec<f32> = (0..batch * 2).map(|_| rng.normal() as f32).collect();
    // Half the rows are zero-gradient padding — must be inert.
    for i in batch / 2..batch {
        grads[i * 2] = 0.0;
        grads[i * 2 + 1] = 0.0;
    }
    let nids: Vec<i32> = (0..batch).map(|_| rng.gen_range(slots as u64) as i32).collect();

    let got = rt.histogram(&bins, &grads, &nids, batch, n_bins).unwrap();
    assert_eq!(got.len(), slots * f_tile * n_bins * 2);

    let mut want = vec![0f64; slots * f_tile * n_bins * 2];
    for r in 0..batch / 2 {
        for f in 0..f_tile {
            let idx = ((nids[r] as usize * f_tile + f) * n_bins
                + bins[r * f_tile + f] as usize)
                * 2;
            want[idx] += grads[r * 2] as f64;
            want[idx + 1] += grads[r * 2 + 1] as f64;
        }
    }
    let mut max_err = 0f64;
    for i in 0..want.len() {
        max_err = max_err.max((got[i] as f64 - want[i]).abs());
    }
    assert!(max_err < 2e-3, "max_err={max_err}");
}

#[test]
fn evaluate_splits_finds_planted_split() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let n_bins = 64usize;
    let f_tile = rt.hist_feature_tile(n_bins).unwrap();
    let slots = rt.hist_node_slots(n_bins).unwrap();
    let mut hist = vec![0f32; slots * f_tile * n_bins * 2];
    // Node 0: feature 3 separates negatives (bins < 20) from positives.
    let f = 3usize;
    for b in 0..n_bins {
        let idx = ((f) * n_bins + b) * 2; // node 0
        hist[idx] = if b < 20 { -1.0 } else { 1.0 };
        hist[idx + 1] = 1.0;
    }
    // Other features of node 0: all mass in one bin (same totals!).
    for of in 0..f_tile {
        if of == f {
            continue;
        }
        let idx = (of * n_bins + 5) * 2;
        hist[idx] = (n_bins as f32) - 40.0; // sum of g = 24 with n_bins=64
        hist[idx + 1] = n_bins as f32;
    }
    let out = rt.evaluate_splits(&hist, 1.0, 0.0, 1.0, n_bins).unwrap();
    assert_eq!(out.gain.len(), slots);
    assert_eq!(out.feature[0], f as i32);
    assert_eq!(out.split_bin[0], 19);
    assert!((out.left_sum[0][0] + 20.0).abs() < 1e-3);
    assert!((out.left_sum[0][1] - 20.0).abs() < 1e-3);
    // Empty node slots are leaves.
    for n in 1..slots {
        assert_eq!(out.feature[n], -1, "slot {n}");
    }
}

#[test]
fn warm_up_compiles_everything() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    rt.warm_up().unwrap();
    assert!(rt.platform().to_lowercase().contains("cpu")
        || rt.platform().to_lowercase().contains("host"));
}
