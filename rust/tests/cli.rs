//! CLI integration: drive the `oocgb` binary end-to-end through
//! datagen → train → predict, plus error paths.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_oocgb"))
}

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("oocgb-cli-{}-{name}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn help_prints_usage() {
    let out = bin().arg("--help").output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn datagen_train_predict_roundtrip() {
    let d = tmpdir("roundtrip");
    let data = d.join("higgs.csv");
    let model = d.join("model.json");
    let preds = d.join("preds.txt");

    let out = bin()
        .args(["datagen", "--kind", "higgs", "--rows", "3000", "--out"])
        .arg(&data)
        .args(["--format", "csv", "--seed", "5"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(data.exists());

    let out = bin()
        .args(["train", "--data"])
        .arg(&data)
        .args(["--format", "csv", "--model-out"])
        .arg(&model)
        .args([
            "n_rounds=5",
            "max_depth=4",
            "max_bin=16",
            "eval_fraction=0.1",
            "eta=0.5",
        ])
        .output()
        .unwrap();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "{stderr}");
    assert!(stderr.contains("trained 5 trees"), "{stderr}");
    assert!(model.exists());

    let out = bin()
        .args(["predict", "--model"])
        .arg(&model)
        .args(["--data"])
        .arg(&data)
        .args(["--format", "csv", "--out"])
        .arg(&preds)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = std::fs::read_to_string(&preds).unwrap();
    let values: Vec<f32> = text.lines().map(|l| l.parse().unwrap()).collect();
    assert_eq!(values.len(), 3000);
    assert!(values.iter().all(|p| (0.0..=1.0).contains(p)));
    std::fs::remove_dir_all(&d).ok();
}

/// datagen → train to a binary bundle → predict/score/serve, asserting
/// the serving paths write byte-identical prediction files to the naive
/// `predict` walk (bit-identity end to end, through text formatting).
#[test]
fn score_and_serve_match_predict() {
    let d = tmpdir("serve");
    let data = d.join("higgs.csv");
    let model = d.join("model.bin");
    let preds = d.join("preds.txt");
    let scored = d.join("scored.txt");
    let served = d.join("served.txt");

    let out = bin()
        .args(["datagen", "--kind", "higgs", "--rows", "2000", "--out"])
        .arg(&data)
        .args(["--format", "csv", "--seed", "9"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin()
        .args(["train", "--data"])
        .arg(&data)
        .args(["--format", "csv", "--model-out"])
        .arg(&model)
        .args(["n_rounds=4", "max_depth=4", "max_bin=32", "eta=0.5"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(model.exists());

    let out = bin()
        .args(["predict", "--model"])
        .arg(&model)
        .arg("--data")
        .arg(&data)
        .args(["--format", "csv", "--out"])
        .arg(&preds)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin()
        .args(["score", "--model"])
        .arg(&model)
        .arg("--data")
        .arg(&data)
        .args(["--format", "csv", "--out"])
        .arg(&scored)
        .args(["workers=3", "block_rows=16"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin()
        .args(["serve", "--model"])
        .arg(&model)
        .arg("--data")
        .arg(&data)
        .args(["--format", "csv", "--out"])
        .arg(&served)
        .args(["batch_max=64", "max_wait_us=200", "workers=2"])
        .output()
        .unwrap();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "{stderr}");
    assert!(stderr.contains("rows/s"), "serve must report throughput: {stderr}");

    let baseline = std::fs::read_to_string(&preds).unwrap();
    assert_eq!(baseline.lines().count(), 2000);
    assert_eq!(std::fs::read_to_string(&scored).unwrap(), baseline);
    assert_eq!(std::fs::read_to_string(&served).unwrap(), baseline);
    std::fs::remove_dir_all(&d).ok();
}

/// `serve` refuses a JSON model (no cuts to compile against).
#[test]
fn serve_requires_binary_bundle() {
    let d = tmpdir("serve-json");
    let data = d.join("higgs.csv");
    let model = d.join("model.json");
    let out = bin()
        .args(["datagen", "--kind", "higgs", "--rows", "500", "--out"])
        .arg(&data)
        .args(["--format", "csv"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = bin()
        .args(["train", "--data"])
        .arg(&data)
        .args(["--format", "csv", "--model-out"])
        .arg(&model)
        .args(["n_rounds=2", "max_depth=3", "max_bin=16"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = bin()
        .args(["serve", "--model"])
        .arg(&model)
        .arg("--data")
        .arg(&data)
        .args(["--format", "csv"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("model.bin"));
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn train_with_mvs_sampling_cpu() {
    let d = tmpdir("mvs");
    let out = bin()
        .args([
            "train",
            "--synthetic",
            "higgs",
            "--rows",
            "2000",
            "n_rounds=3",
            "max_depth=3",
            "max_bin=16",
            "sampling_method=mvs",
            "f=0.4",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    std::fs::remove_dir_all(&d).ok();
}

#[test]
fn bad_config_key_reports_error() {
    let out = bin()
        .args(["train", "--synthetic", "higgs", "--rows", "512", "bogus_key=1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("bogus_key"));
}

#[test]
fn info_lists_artifacts_if_built() {
    // The stub runtime synthesizes an inventory, so this runs on default
    // builds too; PJRT builds need `make artifacts` first.
    if cfg!(feature = "xla") && !std::path::Path::new("artifacts/manifest.json").exists()
    {
        return;
    }
    let out = bin().arg("info").output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("platform"));
    assert!(stdout.contains("hist_b"));
}
