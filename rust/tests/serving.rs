//! Serving-layer integration: compiled-forest bit-identity against
//! `GbtModel::predict` (dense/sparse × missing × n_bins sweep), the
//! batching request front, and binary model persistence.

use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use oocgb::boosting::{load_bundle, load_model_auto, save_bundle, GbtModel, Objective};
use oocgb::config::{ServeConfig, TrainConfig};
use oocgb::coordinator::TrainSession;
use oocgb::data::{synthetic, DMatrix, SparsePage};
use oocgb::ellpack::page::EllpackWriter;
use oocgb::error::Result;
use oocgb::serve::{Batcher, CompiledForest, RowInput, Scorer, ScoringEngine};
use oocgb::sketch::HistogramCuts;
use oocgb::tree::{Node, Tree};
use oocgb::util::prop::{run_prop, Gen};

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("oocgb-serving-{}-{name}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Random strictly-ascending cuts: every feature gets exactly `bins`
/// cut values.
fn random_cuts(g: &mut Gen, n_features: usize, bins: usize) -> HistogramCuts {
    let mut ptrs = vec![0u32];
    let mut values = Vec::new();
    let mut min_vals = Vec::new();
    for _ in 0..n_features {
        let mut v = g.f32_in(-2.0..0.0);
        min_vals.push(v - 1.0);
        for _ in 0..bins {
            v += g.f32_in(0.01..0.8);
            values.push(v);
        }
        ptrs.push(values.len() as u32);
    }
    HistogramCuts { ptrs, values, min_vals }
}

/// Random structural tree consistent with `cuts`: every split's
/// `split_value` is the cut at `(feature, bin)` — the invariant training
/// establishes and `CompiledForest::compile` checks.  `top_bin` = false
/// excludes each feature's last bin (trained models never split there:
/// such a split has an empty right child and non-positive gain).
fn random_tree(g: &mut Gen, cuts: &HistogramCuts, max_depth: usize, top_bin: bool) -> Tree {
    fn build(
        nodes: &mut Vec<Node>,
        g: &mut Gen,
        cuts: &HistogramCuts,
        depth: usize,
        max_depth: usize,
        top_bin: bool,
    ) -> usize {
        let idx = nodes.len();
        if depth >= max_depth || g.usize_in(0..4) == 0 {
            nodes.push(Node::leaf(g.f32_in(-1.0..1.0), 0.0, 1.0, depth));
            return idx;
        }
        let f = g.usize_in(0..cuts.n_features());
        let bins = cuts.n_bins(f);
        let hi = if top_bin { bins } else { bins.max(2) - 1 };
        let bin = g.usize_in(0..hi);
        nodes.push(Node {
            split_feature: f as i32,
            split_bin: bin as i32,
            split_value: cuts.split_value(f, bin as u32),
            left: 0,
            right: 0,
            weight: 0.0,
            gain: 1.0,
            sum_grad: 0.0,
            sum_hess: 2.0,
            depth,
        });
        let l = build(nodes, g, cuts, depth + 1, max_depth, top_bin);
        let r = build(nodes, g, cuts, depth + 1, max_depth, top_bin);
        nodes[idx].left = l;
        nodes[idx].right = r;
        idx
    }
    let mut nodes = Vec::new();
    build(&mut nodes, g, cuts, 0, max_depth, top_bin);
    Tree { nodes }
}

fn random_model(g: &mut Gen, cuts: &HistogramCuts, top_bin: bool) -> GbtModel {
    let obj = if g.bool() { Objective::Logistic } else { Objective::Squared };
    let mut m = GbtModel::new(obj, cuts.n_features());
    for _ in 0..g.usize_in(1..5) {
        m.trees.push(random_tree(g, cuts, g.usize_in(1..6), top_bin));
    }
    m
}

/// One random feature value for `f`: mostly in-range, sometimes exactly
/// a cut (boundary), sometimes NaN (missing), below min, or — when
/// `beyond` — above the last cut.
fn random_value(g: &mut Gen, cuts: &HistogramCuts, f: usize, beyond: bool) -> f32 {
    let fc = cuts.feature_cuts(f);
    let last = *fc.last().unwrap();
    match g.usize_in(0..10) {
        0 => f32::NAN,
        1 => fc[g.usize_in(0..fc.len())], // exact cut boundary
        2 => cuts.min_vals[f] - g.f32_in(0.0..2.0),
        3 if beyond => last + g.f32_in(0.001..3.0),
        _ => {
            let lo = cuts.min_vals[f];
            lo + g.f32_in(0.0..1.0) * (last - lo)
        }
    }
}

/// Random dataset over the cuts' feature space: sparse rows (features
/// dropped ⇒ missing) or dense rows (all present, NaN ⇒ missing).
fn random_data(g: &mut Gen, cuts: &HistogramCuts, rows: usize, beyond: bool) -> DMatrix {
    let nf = cuts.n_features();
    let mut page = SparsePage::new(nf);
    let dense = g.bool();
    for _ in 0..rows {
        if dense {
            let vals: Vec<f32> =
                (0..nf).map(|f| random_value(g, cuts, f, beyond)).collect();
            page.push_dense_row(&vals);
        } else {
            let mut cols = Vec::new();
            let mut vals = Vec::new();
            for f in 0..nf {
                if g.usize_in(0..10) < 7 {
                    cols.push(f as u32);
                    vals.push(random_value(g, cuts, f, beyond));
                }
            }
            page.push_row(&cols, &vals);
        }
    }
    let labels = vec![0.0; rows];
    DMatrix::from_page(page, labels).unwrap()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: row {i} differs ({x} vs {y})"
        );
    }
}

/// Core equivalence sweep shared by the in-range and beyond-range
/// properties: engine binned, raw, and mixed request paths must be
/// bit-identical to the naive model walk.
fn check_equivalence(g: &mut Gen, bins: usize, beyond: bool) {
    let nf = g.usize_in(1..7);
    let cuts = random_cuts(g, nf, bins);
    let model = random_model(g, &cuts, !beyond);
    let data = random_data(g, &cuts, g.usize_in(1..40), beyond);
    let expected = model.predict(&data);

    let forest = Arc::new(CompiledForest::compile(&model, &cuts).unwrap());
    let block = [1usize, 7, 64][g.usize_in(0..3)];
    let workers = g.usize_in(1..4);
    let engine = ScoringEngine::new(Arc::clone(&forest))
        .with_block_rows(block)
        .with_workers(workers);

    let binned = engine.score_dmatrix(&data, Some(&cuts)).unwrap();
    assert_bits_eq(&binned, &expected, "binned path");
    let raw = engine.score_dmatrix(&data, None).unwrap();
    assert_bits_eq(&raw, &expected, "raw path");

    // Mixed per-request path (what the batcher drives).
    let rows: Vec<RowInput> = (0..data.n_rows())
        .map(|r| {
            let (cols, vals) = data.row(r);
            if g.bool() {
                let mut syms = vec![0u32; nf];
                forest.quantize_row_into(&cuts, cols, vals, &mut syms);
                RowInput::Binned(syms)
            } else {
                let mut dense = vec![f32::NAN; nf];
                for (c, v) in cols.iter().zip(vals) {
                    dense[*c as usize] = *v;
                }
                RowInput::Raw(dense)
            }
        })
        .collect();
    let mixed = engine.score_rows(&rows).unwrap();
    assert_bits_eq(&mixed, &expected, "mixed request path");
}

#[test]
fn compiled_engine_matches_model_in_range() {
    for bins in [2usize, 64, 256] {
        run_prop(&format!("serve equivalence bins={bins}"), 40, |g| {
            // Splits may use any bin; values stay ≤ the last cut.
            check_equivalence(g, bins, false);
        });
    }
}

#[test]
fn compiled_engine_matches_model_beyond_sketch_range() {
    for bins in [2usize, 64, 256] {
        run_prop(&format!("serve beyond-range bins={bins}"), 40, |g| {
            // Values may exceed the last cut; splits avoid the top bin,
            // as trained models do.
            check_equivalence(g, bins, true);
        });
    }
}

#[test]
fn score_page_matches_model_dense_and_sparse() {
    run_prop("score_page equivalence", 40, |g| {
        let nf = g.usize_in(1..6);
        let cuts = random_cuts(g, nf, g.usize_in(2..17));
        let model = random_model(g, &cuts, true);
        let data = random_data(g, &cuts, g.usize_in(1..30), false);
        let expected = model.predict(&data);
        let forest = Arc::new(CompiledForest::compile(&model, &cuts).unwrap());
        let engine = ScoringEngine::new(Arc::clone(&forest));
        let n_symbols = forest.total_symbols();
        let null = forest.null_symbol();

        // Dense page: feature f at position f.
        let mut w = EllpackWriter::new(data.n_rows(), nf, n_symbols, true);
        let mut syms = vec![0u32; nf];
        for r in 0..data.n_rows() {
            let (cols, vals) = data.row(r);
            forest.quantize_row_into(&cuts, cols, vals, &mut syms);
            w.push_row(&syms);
        }
        let dense_page = w.finish(0);
        assert_bits_eq(
            &engine.score_page(&dense_page).unwrap(),
            &expected,
            "dense page",
        );

        // Sparse page: only present symbols, null-padded to the stride.
        let mut rows_syms: Vec<Vec<u32>> = Vec::new();
        for r in 0..data.n_rows() {
            let (cols, vals) = data.row(r);
            forest.quantize_row_into(&cuts, cols, vals, &mut syms);
            rows_syms.push(syms.iter().copied().filter(|&s| s != null).collect());
        }
        let stride = rows_syms.iter().map(Vec::len).max().unwrap_or(0).max(1);
        let mut w = EllpackWriter::new(data.n_rows(), stride, n_symbols, false);
        for row in &rows_syms {
            w.push_row(row);
        }
        let sparse_page = w.finish(0);
        assert_bits_eq(
            &engine.score_page(&sparse_page).unwrap(),
            &expected,
            "sparse page",
        );
    });
}

#[test]
fn compile_rejects_foreign_cuts_and_bad_trees() {
    run_prop("compile validation", 20, |g| {
        let cuts = random_cuts(g, 3, 8);
        let model = random_model(g, &cuts, true);
        // Identical cuts compile...
        CompiledForest::compile(&model, &cuts).unwrap();
        // ...a feature-count mismatch is always caught...
        let wider = random_cuts(g, 4, 8);
        assert!(CompiledForest::compile(&model, &wider).is_err());
        if model.trees.iter().all(|t| t.nodes.len() == 1) {
            return; // all-leaf forest can't detect same-shape foreign cuts
        }
        // ...and perturbing the cut values the model split on is caught
        // by the strict split_value == cut bit check.
        let mut foreign = cuts.clone();
        for v in foreign.values.iter_mut() {
            *v += 0.001;
        }
        assert!(CompiledForest::compile(&model, &foreign).is_err());
    });
}

// ---- persistence ----

#[test]
fn bundle_roundtrip_is_bit_exact() {
    run_prop("bundle roundtrip", 20, |g| {
        let d = tmpdir(&format!("rt-{}", g.case_seed));
        let path = d.join("model.bin");
        let cuts = random_cuts(g, g.usize_in(1..5), g.usize_in(2..20));
        let model = random_model(g, &cuts, true);
        let with_cuts = g.bool();
        save_bundle(&path, &model, if with_cuts { Some(&cuts) } else { None }).unwrap();
        let bundle = load_bundle(&path).unwrap();
        assert_eq!(bundle.model.objective, model.objective);
        assert_eq!(bundle.model.base_margin.to_bits(), model.base_margin.to_bits());
        assert_eq!(bundle.model.n_features, model.n_features);
        assert_eq!(bundle.model.trees, model.trees);
        match (&bundle.cuts, with_cuts) {
            (Some(c), true) => {
                assert_eq!(c.ptrs, cuts.ptrs);
                let bits =
                    |v: &Vec<f32>| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&c.values), bits(&cuts.values));
                assert_eq!(bits(&c.min_vals), bits(&cuts.min_vals));
                // The strict compile-time cut check survives the round trip.
                CompiledForest::compile(&bundle.model, c).unwrap();
            }
            (None, false) => {}
            _ => panic!("cuts presence not preserved"),
        }
        std::fs::remove_dir_all(&d).ok();
    });
}

#[test]
fn bundle_detects_corruption() {
    let d = tmpdir("corrupt");
    let path = d.join("model.bin");
    run_prop("make model", 1, |g| {
        let cuts = random_cuts(g, 3, 8);
        let model = random_model(g, &cuts, true);
        save_bundle(&path, &model, Some(&cuts)).unwrap();
    });
    let good = std::fs::read(&path).unwrap();

    // Flip one payload byte → checksum error.
    let mut bad = good.clone();
    bad[50] ^= 0xff;
    std::fs::write(&path, &bad).unwrap();
    let err = load_bundle(&path).unwrap_err().to_string();
    assert!(err.contains("checksum"), "{err}");

    // Truncate → truncation error.
    std::fs::write(&path, &good[..good.len() / 2]).unwrap();
    assert!(load_bundle(&path).is_err());

    // Bad magic → "not a bundle".
    let mut bad = good.clone();
    bad[0] ^= 0xff;
    std::fs::write(&path, &bad).unwrap();
    let err = load_bundle(&path).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");

    // Future version → unsupported.
    let mut bad = good.clone();
    bad[8] = 99;
    std::fs::write(&path, &bad).unwrap();
    let err = load_bundle(&path).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    std::fs::remove_dir_all(&d).ok();
}

/// End-to-end on a really trained model: train → compile → score on all
/// paths, through a save/load cycle, across worker counts.
#[test]
fn trained_model_serves_bit_identically() {
    let data = synthetic::higgs_like(1500, 11);
    let mut cfg = TrainConfig::default();
    cfg.n_rounds = 5;
    cfg.max_depth = 4;
    cfg.max_bin = 16;
    let session = TrainSession::from_memory(data, cfg).unwrap();
    let outcome = session.train().unwrap();
    let data = synthetic::higgs_like(1500, 11); // same seed ⇒ same rows
    let expected = outcome.model.predict(&data);
    let trained_cuts: &HistogramCuts = &outcome.cuts;

    let forest = Arc::new(CompiledForest::compile(&outcome.model, trained_cuts).unwrap());
    for workers in [1usize, 4] {
        let engine = ScoringEngine::new(Arc::clone(&forest)).with_workers(workers);
        let binned = engine.score_dmatrix(&data, Some(trained_cuts)).unwrap();
        assert_bits_eq(&binned, &expected, "trained binned");
        let raw = engine.score_dmatrix(&data, None).unwrap();
        assert_bits_eq(&raw, &expected, "trained raw");
    }

    // Through the binary bundle.
    let d = tmpdir("trained");
    let path = d.join("model.bin");
    save_bundle(&path, &outcome.model, Some(trained_cuts)).unwrap();
    let bundle = load_model_auto(&path).unwrap();
    let cuts = bundle.cuts.expect("bundle carries cuts");
    let forest = Arc::new(CompiledForest::compile(&bundle.model, &cuts).unwrap());
    let engine = ScoringEngine::new(forest);
    let binned = engine.score_dmatrix(&data, Some(&cuts)).unwrap();
    assert_bits_eq(&binned, &expected, "reloaded binned");

    // And through the JSON dump (auto-detected, no cuts → naive walk in
    // the CLI; here we check the model itself survives).
    let jpath = d.join("model.json");
    outcome.model.save(&jpath).unwrap();
    let jbundle = load_model_auto(&jpath).unwrap();
    assert!(jbundle.cuts.is_none());
    assert_bits_eq(&jbundle.model.predict(&data), &expected, "json reload");
    std::fs::remove_dir_all(&d).ok();
}

// ---- batcher ----

/// Test scorer: blocks every batch behind a gate (closed ⇒ workers
/// stall, for backpressure/shutdown tests) and records batch sizes.
struct GatedScorer {
    nf: usize,
    open: Mutex<bool>,
    cv: Condvar,
    batches: Mutex<Vec<usize>>,
}

impl GatedScorer {
    fn new(nf: usize, open: bool) -> GatedScorer {
        GatedScorer {
            nf,
            open: Mutex::new(open),
            cv: Condvar::new(),
            batches: Mutex::new(Vec::new()),
        }
    }

    fn open_gate(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn batch_sizes(&self) -> Vec<usize> {
        self.batches.lock().unwrap().clone()
    }
}

impl Scorer for GatedScorer {
    fn n_features(&self) -> usize {
        self.nf
    }

    fn score_rows(&self, rows: &[RowInput]) -> Result<Vec<f32>> {
        let mut g = self.open.lock().unwrap();
        while !*g {
            g = self.cv.wait(g).unwrap();
        }
        drop(g);
        self.batches.lock().unwrap().push(rows.len());
        Ok(rows
            .iter()
            .map(|r| match r {
                RowInput::Raw(v) => v[0],
                RowInput::Binned(s) => s[0] as f32,
            })
            .collect())
    }
}

fn serve_cfg(batch_max: usize, max_wait_us: usize, queue_depth: usize, workers: usize) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.batch_max = batch_max;
    cfg.max_wait_us = max_wait_us;
    cfg.queue_depth = queue_depth;
    cfg.workers = workers;
    cfg
}

#[test]
fn batcher_flushes_on_deadline() {
    // batch_max is far above the request count, so only the max-wait
    // deadline can flush the batch.
    let scorer = Arc::new(GatedScorer::new(1, true));
    let batcher = Batcher::new(Arc::clone(&scorer) as Arc<dyn Scorer>, &serve_cfg(1000, 100_000, 64, 1));
    let replies: Vec<_> = (0..3)
        .map(|i| batcher.submit(RowInput::Raw(vec![i as f32])).unwrap())
        .collect();
    for (i, r) in replies.into_iter().enumerate() {
        assert_eq!(r.wait().unwrap(), i as f32);
    }
    assert_eq!(scorer.batch_sizes(), vec![3], "one deadline-flushed batch");
    let report = batcher.report();
    assert_eq!(report.rows, 3);
    assert_eq!(report.batches, 1);
    assert!(report.p99_us >= report.p50_us);
    assert!(report.p50_us > 0.0);
}

#[test]
fn batcher_delivers_replies_in_order() {
    let scorer = Arc::new(GatedScorer::new(1, true));
    let batcher = Batcher::new(scorer as Arc<dyn Scorer>, &serve_cfg(8, 1000, 16, 2));
    let replies: Vec<_> = (0..100)
        .map(|i| batcher.submit(RowInput::Raw(vec![i as f32])).unwrap())
        .collect();
    for (i, r) in replies.into_iter().enumerate() {
        assert_eq!(r.wait().unwrap(), i as f32, "reply {i} crossed wires");
    }
    let report = batcher.report();
    assert_eq!(report.rows, 100);
    assert!(report.batches >= 13, "batch_max=8 ⇒ at least ceil(100/8) batches");
}

#[test]
fn batcher_backpressure_bounds_the_queue() {
    // Gate closed: the worker stalls, every buffer fills, and
    // try_submit must eventually reject instead of queueing unboundedly.
    let scorer = Arc::new(GatedScorer::new(1, false));
    let batcher = Batcher::new(Arc::clone(&scorer) as Arc<dyn Scorer>, &serve_cfg(1, 100, 1, 1));
    let mut accepted = Vec::new();
    let mut saw_full = false;
    for i in 0..20 {
        match batcher.try_submit(RowInput::Raw(vec![i as f32])) {
            Ok(r) => accepted.push((i, r)),
            Err(e) => {
                assert!(e.to_string().contains("full"), "{e}");
                saw_full = true;
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(saw_full, "bounded queues must reject when the engine stalls");
    assert!(accepted.len() >= 2, "some requests should be in flight");
    scorer.open_gate();
    for (i, r) in accepted {
        assert_eq!(r.wait().unwrap(), i as f32);
    }
}

#[test]
fn batcher_drop_flushes_and_joins() {
    let scorer = Arc::new(GatedScorer::new(1, false));
    let batcher = Batcher::new(Arc::clone(&scorer) as Arc<dyn Scorer>, &serve_cfg(16, 2000, 16, 2));
    let replies: Vec<_> = (0..5)
        .map(|i| batcher.submit(RowInput::Raw(vec![i as f32])).unwrap())
        .collect();
    // Open the gate shortly after drop starts joining the pipeline.
    let s = Arc::clone(&scorer);
    let opener = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(50));
        s.open_gate();
    });
    drop(batcher); // must flush pending batches and join every thread
    opener.join().unwrap();
    for (i, r) in replies.into_iter().enumerate() {
        assert_eq!(r.wait().unwrap(), i as f32, "pending request {i} lost at shutdown");
    }
}

#[test]
fn batcher_rejects_malformed_rows() {
    let scorer = Arc::new(GatedScorer::new(3, true));
    let batcher = Batcher::new(scorer as Arc<dyn Scorer>, &serve_cfg(4, 100, 8, 1));
    let err = batcher.submit(RowInput::Raw(vec![1.0])).unwrap_err();
    assert!(err.to_string().contains("features"), "{err}");
    let ok = batcher.submit(RowInput::Binned(vec![0, 1, 2])).unwrap();
    assert_eq!(ok.wait().unwrap(), 0.0);
}
