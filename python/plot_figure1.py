"""Render the paper's Figure 1 (training curves vs sampling rate) from
the CSV emitted by `cargo bench --bench bench_figure1`.

Usage:
    python python/plot_figure1.py [figure1_curves.csv] [figure1.png]
"""

import csv
import sys


def main() -> None:
    src = sys.argv[1] if len(sys.argv) > 1 else "figure1_curves.csv"
    dst = sys.argv[2] if len(sys.argv) > 2 else "figure1.png"
    with open(src) as f:
        reader = csv.reader(f)
        header = next(reader)
        rows = [[float(x) for x in row] for row in reader]
    rounds = [r[0] for r in rows]

    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(7, 4.5))
    for i, label in enumerate(header[1:], start=1):
        ax.plot(rounds, [r[i] for r in rows], label=label.replace("f", "f = "))
    ax.set_xlabel("iteration")
    ax.set_ylabel("eval AUC")
    ax.set_title("Training curves on the Higgs-like dataset (paper Figure 1)")
    ax.legend(loc="lower right")
    ax.grid(alpha=0.3)
    fig.tight_layout()
    fig.savefig(dst, dpi=150)
    print(f"wrote {dst}")


if __name__ == "__main__":
    main()
