"""AOT pipeline tests: every artifact lowers, the HLO text parses as HLO
(sanity), the manifest is complete/consistent, and regeneration is
deterministic (so `make artifacts` is reproducible)."""

import json
import os
import tempfile

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    entries = []
    for name, kind, params, fn, specs in aot.build_artifacts():
        entries.append((name, kind, params, fn, specs))
    return out, entries


def test_artifact_inventory(built):
    _, entries = built
    kinds = {}
    for name, kind, *_ in entries:
        kinds.setdefault(kind, []).append(name)
    assert len(kinds["histogram"]) == 4       # 2 batches × 2 bin widths
    assert len(kinds["gradient"]) == 4        # 2 batches × 2 objectives
    assert len(kinds["mvs"]) == 2
    assert len(kinds["eval_splits"]) == 2
    names = [n for n, *_ in entries]
    assert len(names) == len(set(names)), "artifact names must be unique"


def test_each_artifact_lowers_to_hlo_text(built):
    import jax
    _, entries = built
    for name, kind, params, fn, specs in entries:
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_lowering_is_deterministic():
    """Same graph → same HLO text (reproducible builds)."""
    import jax
    entry = next(iter(aot.build_artifacts()))
    _, _, _, fn, specs = entry
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert t1 == t2


def test_main_writes_manifest_and_files(tmp_path, monkeypatch):
    out = str(tmp_path / "a")
    monkeypatch.setattr("sys.argv", ["aot", "--out-dir", out])
    aot.main()
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == 1
    assert len(manifest["artifacts"]) == 12
    for art in manifest["artifacts"]:
        path = os.path.join(out, art["file"])
        assert os.path.exists(path), art["file"]
        with open(path) as f:
            assert f.read(9) == "HloModule"
        # Signature sanity: histogram takes 3 inputs, returns 1 output.
        if art["kind"] == "histogram":
            assert len(art["inputs"]) == 3
            assert len(art["outputs"]) == 1
            b = art["params"]["batch"]
            assert art["inputs"][0]["shape"] == [b, art["params"]["features"]]
            assert art["outputs"][0]["shape"] == [
                art["params"]["nodes"], art["params"]["features"],
                art["params"]["bins"], 2]
        if art["kind"] == "mvs":
            assert len(art["outputs"]) == 2  # scores + sum


def test_repo_manifest_matches_inventory():
    """The checked-in artifacts/ dir (if built) agrees with build_artifacts."""
    here = os.path.dirname(os.path.dirname(os.path.dirname(__file__)))
    manifest_path = os.path.join(here, "artifacts", "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts/ not built yet")
    with open(manifest_path) as f:
        manifest = json.load(f)
    built_names = {n for n, *_ in aot.build_artifacts()}
    manifest_names = {a["name"] for a in manifest["artifacts"]}
    assert built_names == manifest_names
