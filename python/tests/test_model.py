"""L2 graph correctness: model.py vs ref.py, plus split-evaluator edge
cases that the Rust coordinator relies on."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _params(lam=1.0, gamma=0.0, mcw=1.0):
    return jnp.array([lam, gamma, mcw], dtype=jnp.float32)


def _hist_from_data(seed, rows, features, n_nodes, n_bins):
    """Build a *consistent* histogram (as real training produces) so that
    per-feature totals agree."""
    rng = np.random.default_rng(seed)
    bins = rng.integers(0, n_bins, (rows, features)).astype(np.int32)
    grads = rng.normal(size=(rows, 2)).astype(np.float32)
    grads[:, 1] = np.abs(grads[:, 1]) + 0.05  # hessians positive
    nids = rng.integers(0, n_nodes, rows).astype(np.int32)
    return ref.histogram_ref(bins, grads, nids, n_nodes, n_bins)


class TestEvaluateSplits:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n_nodes=st.sampled_from([1, 2, 8]),
           features=st.sampled_from([1, 4, 11]),
           n_bins=st.sampled_from([4, 16, 64]),
           lam=st.sampled_from([0.5, 1.0, 5.0]),
           gamma=st.sampled_from([0.0, 0.5]))
    def test_matches_ref(self, seed, n_nodes, features, n_bins, lam, gamma):
        hist = _hist_from_data(seed, 512, features, n_nodes, n_bins)
        gain, feat, sbin, left, total = model.evaluate_splits(
            jnp.array(hist), _params(lam, gamma, 1.0))
        r = ref.evaluate_splits_ref(hist, lam, gamma, 1.0)
        np.testing.assert_allclose(np.asarray(gain), r["gain"], rtol=1e-3,
                                   atol=1e-3)
        # Ties may fall either way under fp reassociation; when the chosen
        # (feature, bin) differ, the gains must still agree.
        same = np.asarray(feat) == r["feature"]
        np.testing.assert_array_equal(np.asarray(sbin)[same],
                                      r["split_bin"][same])
        np.testing.assert_allclose(np.asarray(total), r["total"], rtol=1e-3,
                                   atol=1e-3)

    def test_pure_node_has_no_split(self):
        """A node whose gradient mass sits in a single bin can't split."""
        n_bins = 8
        hist = np.zeros((1, 2, n_bins, 2), dtype=np.float32)
        hist[0, :, 3, 0] = -4.0
        hist[0, :, 3, 1] = 5.0
        gain, feat, sbin, left, total = model.evaluate_splits(
            jnp.array(hist), _params())
        assert np.asarray(feat)[0] == -1
        assert np.asarray(gain)[0] == 0.0
        np.testing.assert_allclose(np.asarray(total)[0], [-4.0, 5.0])

    def test_perfectly_separable_splits_at_boundary(self):
        """Negative gradients in low bins, positive in high bins → the
        evaluator must split exactly between them."""
        n_bins = 16
        hist = np.zeros((1, 1, n_bins, 2), dtype=np.float32)
        hist[0, 0, :8, 0] = -1.0
        hist[0, 0, 8:, 0] = 1.0
        hist[0, 0, :, 1] = 1.0
        gain, feat, sbin, left, total = model.evaluate_splits(
            jnp.array(hist), _params(lam=1.0, gamma=0.0, mcw=1.0))
        assert np.asarray(feat)[0] == 0
        assert np.asarray(sbin)[0] == 7
        np.testing.assert_allclose(np.asarray(left)[0], [-8.0, 8.0])

    def test_min_child_weight_blocks_small_children(self):
        n_bins = 8
        hist = np.zeros((1, 1, n_bins, 2), dtype=np.float32)
        hist[0, 0, 0] = (-1.0, 0.5)   # tiny left child
        hist[0, 0, 7] = (10.0, 20.0)
        gain, feat, _, _, _ = model.evaluate_splits(
            jnp.array(hist), _params(lam=1.0, gamma=0.0, mcw=1.0))
        assert np.asarray(feat)[0] == -1  # hl=0.5 < mcw for every cut

    def test_gamma_penalty_suppresses_weak_splits(self):
        hist = _hist_from_data(7, 256, 3, 1, 16)
        g0 = np.asarray(model.evaluate_splits(jnp.array(hist),
                                              _params(gamma=0.0))[0])
        g_big = model.evaluate_splits(jnp.array(hist),
                                      _params(gamma=float(g0[0] + 1.0)))
        assert np.asarray(g_big[1])[0] == -1

    def test_padded_feature_in_last_bin_never_selected(self):
        """Rust pads features to the tile width with bin = n_bins-1; such
        a column must never win a split."""
        n_bins = 8
        hist = _hist_from_data(9, 512, 2, 1, n_bins)
        padded = np.zeros((1, 1, n_bins, 2), dtype=np.float32)
        padded[0, 0, n_bins - 1] = hist[0, 0].sum(axis=0)
        full = np.concatenate([hist, padded], axis=1)
        _, feat, _, _, _ = model.evaluate_splits(jnp.array(full), _params())
        assert np.asarray(feat)[0] != 2

    def test_empty_node_slots_are_leaves(self):
        """Node slots with no rows (zero histogram) must return no split."""
        hist = np.zeros((4, 2, 8, 2), dtype=np.float32)
        hist[0] = _hist_from_data(11, 256, 2, 1, 8)[0]
        _, feat, _, _, _ = model.evaluate_splits(jnp.array(hist), _params())
        assert np.all(np.asarray(feat)[1:] == -1)


class TestHistogramStep:
    def test_wraps_kernel(self):
        rng = np.random.default_rng(3)
        bins = rng.integers(0, 16, (512, 4)).astype(np.int32)
        grads = rng.normal(size=(512, 2)).astype(np.float32)
        nids = rng.integers(0, 4, 512).astype(np.int32)
        (out,) = model.histogram_step(jnp.array(bins), jnp.array(grads),
                                      jnp.array(nids), n_nodes=4, n_bins=16,
                                      row_block=128)
        np.testing.assert_allclose(np.asarray(out),
                                   ref.histogram_ref(bins, grads, nids, 4,
                                                     16),
                                   rtol=1e-4, atol=1e-4)


class TestGradientStep:
    @pytest.mark.parametrize("objective,oracle", [
        ("binary:logistic", ref.logistic_gradients_ref),
        ("reg:squarederror", ref.squared_gradients_ref),
    ])
    def test_objectives(self, objective, oracle):
        rng = np.random.default_rng(4)
        preds = rng.normal(size=8192).astype(np.float32)
        labels = (rng.random(8192) < 0.4).astype(np.float32)
        (out,) = model.gradient_step(jnp.array(preds), jnp.array(labels),
                                     objective=objective)
        np.testing.assert_allclose(np.asarray(out), oracle(preds, labels),
                                   rtol=1e-5, atol=1e-6)

    def test_unknown_objective_raises(self):
        with pytest.raises(ValueError):
            model.gradient_step(jnp.zeros(8192), jnp.zeros(8192),
                                objective="rank:pairwise")


class TestMvsStep:
    def test_scores_and_sum(self):
        rng = np.random.default_rng(5)
        grads = rng.normal(size=(8192, 2)).astype(np.float32)
        scores, total = model.mvs_step(jnp.array(grads),
                                       jnp.array([0.7], dtype=np.float32))
        expect = ref.mvs_scores_ref(grads, 0.7)
        np.testing.assert_allclose(np.asarray(scores), expect, rtol=1e-5,
                                   atol=1e-6)
        np.testing.assert_allclose(float(total), expect.sum(), rtol=1e-4)
