"""L1 kernel correctness: Pallas kernels vs pure-numpy oracles (ref.py).

Hypothesis sweeps shapes/dtypes/value ranges; fixed-seed cases pin the
exact configurations the AOT artifacts are compiled with.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import (
    build_histogram_scatter,
    build_histogram_onehot,
    logistic_gradients,
    squared_gradients,
    mvs_scores,
)
from compile.kernels import ref

HIST_TOL = dict(rtol=1e-4, atol=1e-4)
ELEM_TOL = dict(rtol=1e-5, atol=1e-6)


def _hist_case(seed, rows, features, n_nodes, n_bins, zero_frac=0.0):
    rng = np.random.default_rng(seed)
    bins = rng.integers(0, n_bins, (rows, features)).astype(np.int32)
    grads = rng.normal(size=(rows, 2)).astype(np.float32)
    if zero_frac:
        mask = rng.random(rows) < zero_frac
        grads[mask] = 0.0
    nids = rng.integers(0, n_nodes, rows).astype(np.int32)
    return bins, grads, nids


class TestHistogramScatter:
    @pytest.mark.parametrize("rows,rb", [(1024, 256), (2048, 2048),
                                         (4096, 1024)])
    def test_matches_ref(self, rows, rb):
        bins, grads, nids = _hist_case(0, rows, 8, 4, 16)
        out = build_histogram_scatter(jnp.array(bins), jnp.array(grads),
                                      jnp.array(nids), n_nodes=4, n_bins=16,
                                      row_block=rb)
        expect = ref.histogram_ref(bins, grads, nids, 4, 16)
        np.testing.assert_allclose(np.asarray(out), expect, **HIST_TOL)

    def test_zero_grad_rows_are_inert(self):
        """Padding contract: zero-gradient rows contribute nothing."""
        bins, grads, nids = _hist_case(1, 1024, 4, 4, 16)
        grads[512:] = 0.0
        full = build_histogram_scatter(jnp.array(bins), jnp.array(grads),
                                       jnp.array(nids), n_nodes=4, n_bins=16,
                                       row_block=256)
        expect = ref.histogram_ref(bins[:512], grads[:512], nids[:512], 4, 16)
        np.testing.assert_allclose(np.asarray(full), expect, **HIST_TOL)

    def test_single_node(self):
        bins, grads, _ = _hist_case(2, 512, 4, 1, 8)
        nids = np.zeros(512, dtype=np.int32)
        out = build_histogram_scatter(jnp.array(bins), jnp.array(grads),
                                      jnp.array(nids), n_nodes=1, n_bins=8,
                                      row_block=512)
        expect = ref.histogram_ref(bins, grads, nids, 1, 8)
        np.testing.assert_allclose(np.asarray(out), expect, **HIST_TOL)

    def test_histogram_sums_to_gradient_total(self):
        """Invariant: Σ over (node, bin) of hist[..., k] = Σ grads[:, k] per
        feature."""
        bins, grads, nids = _hist_case(3, 2048, 6, 8, 32)
        out = np.asarray(build_histogram_scatter(
            jnp.array(bins), jnp.array(grads), jnp.array(nids), n_nodes=8,
            n_bins=32, row_block=512))
        per_feature = out.sum(axis=(0, 2))  # [F, 2]
        total = grads.sum(axis=0)
        for f in range(6):
            np.testing.assert_allclose(per_feature[f], total, rtol=1e-3,
                                       atol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        log_rows=st.integers(7, 11),
        features=st.integers(1, 12),
        n_nodes=st.sampled_from([1, 2, 4, 8, 32]),
        n_bins=st.sampled_from([2, 8, 16, 64]),
        zero_frac=st.sampled_from([0.0, 0.25, 1.0]),
    )
    def test_property_sweep(self, seed, log_rows, features, n_nodes, n_bins,
                            zero_frac):
        rows = 2 ** log_rows
        bins, grads, nids = _hist_case(seed, rows, features, n_nodes, n_bins,
                                       zero_frac)
        out = build_histogram_scatter(jnp.array(bins), jnp.array(grads),
                                      jnp.array(nids), n_nodes=n_nodes,
                                      n_bins=n_bins, row_block=128)
        expect = ref.histogram_ref(bins, grads, nids, n_nodes, n_bins)
        np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-3,
                                   atol=1e-3)


class TestHistogramOnehot:
    """The MXU (one-hot matmul) formulation must equal the scatter kernel."""

    @pytest.mark.parametrize("n_nodes,n_bins", [(1, 16), (4, 16), (8, 32)])
    def test_matches_ref(self, n_nodes, n_bins):
        bins, grads, nids = _hist_case(4, 1024, 6, n_nodes, n_bins)
        out = build_histogram_onehot(jnp.array(bins), jnp.array(grads),
                                     jnp.array(nids), n_nodes=n_nodes,
                                     n_bins=n_bins, row_block=256)
        expect = ref.histogram_ref(bins, grads, nids, n_nodes, n_bins)
        np.testing.assert_allclose(np.asarray(out), expect, **HIST_TOL)

    def test_equals_scatter_kernel(self):
        bins, grads, nids = _hist_case(5, 2048, 4, 4, 16)
        a = build_histogram_onehot(jnp.array(bins), jnp.array(grads),
                                   jnp.array(nids), n_nodes=4, n_bins=16,
                                   row_block=512)
        b = build_histogram_scatter(jnp.array(bins), jnp.array(grads),
                                    jnp.array(nids), n_nodes=4, n_bins=16,
                                    row_block=512)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


class TestGradients:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), log_rows=st.integers(7, 13),
           scale=st.sampled_from([0.1, 1.0, 10.0]))
    def test_logistic_sweep(self, seed, log_rows, scale):
        rows = 2 ** log_rows
        rng = np.random.default_rng(seed)
        preds = (rng.normal(size=rows) * scale).astype(np.float32)
        labels = (rng.random(rows) < 0.5).astype(np.float32)
        out = logistic_gradients(jnp.array(preds), jnp.array(labels),
                                 row_block=128)
        np.testing.assert_allclose(np.asarray(out),
                                   ref.logistic_gradients_ref(preds, labels),
                                   **ELEM_TOL)

    def test_logistic_extreme_margins_hessian_clamped(self):
        preds = np.array([-40.0, 40.0, 0.0, -1e3, 1e3], dtype=np.float32)
        preds = np.tile(preds, 26)[:128]
        labels = np.zeros(128, dtype=np.float32)
        out = np.asarray(logistic_gradients(jnp.array(preds),
                                            jnp.array(labels),
                                            row_block=128))
        assert np.all(out[:, 1] >= 1e-16)
        assert np.all(np.isfinite(out))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), log_rows=st.integers(7, 12))
    def test_squared_sweep(self, seed, log_rows):
        rows = 2 ** log_rows
        rng = np.random.default_rng(seed)
        preds = rng.normal(size=rows).astype(np.float32)
        labels = rng.normal(size=rows).astype(np.float32)
        out = squared_gradients(jnp.array(preds), jnp.array(labels),
                                row_block=128)
        np.testing.assert_allclose(np.asarray(out),
                                   ref.squared_gradients_ref(preds, labels),
                                   **ELEM_TOL)


class TestMvs:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), log_rows=st.integers(7, 12),
           lam=st.sampled_from([0.0, 0.1, 1.0, 10.0]))
    def test_scores_sweep(self, seed, log_rows, lam):
        rows = 2 ** log_rows
        rng = np.random.default_rng(seed)
        grads = rng.normal(size=(rows, 2)).astype(np.float32)
        out = mvs_scores(jnp.array(grads),
                         jnp.array([lam], dtype=np.float32), row_block=128)
        np.testing.assert_allclose(np.asarray(out),
                                   ref.mvs_scores_ref(grads, lam), **ELEM_TOL)

    def test_scores_nonnegative_and_monotone_in_gradient(self):
        g = np.linspace(-5, 5, 128, dtype=np.float32)
        grads = np.stack([g, np.ones_like(g)], axis=-1)
        out = np.asarray(mvs_scores(jnp.array(grads),
                                    jnp.array([1.0], dtype=np.float32),
                                    row_block=128))
        assert np.all(out >= 1.0 - 1e-6)  # sqrt(g² + 1) ≥ 1
        assert np.all(np.diff(out[:64]) <= 1e-6)  # |g| decreasing half
        assert np.all(np.diff(out[64:]) >= -1e-6)
