"""Minimal Variance Sampling score kernel (paper Eq. 9).

MVS samples each row with probability proportional to the *regularized
absolute gradient*::

    ĝ_i = sqrt(g_i² + λ h_i²)

The score computation is the device-side half of the sampler (elementwise,
one pass over the gradient pairs); the threshold search and the Bernoulli /
Poisson draws stay in the Rust coordinator, which is exactly how the paper's
implementation splits the work between GPU kernels and host logic.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mvs_kernel(grads_ref, lam_ref, out_ref):
    g = grads_ref[..., 0]
    h = grads_ref[..., 1]
    lam = lam_ref[0]
    out_ref[...] = jnp.sqrt(g * g + lam * h * h)


def mvs_scores(grads, lam, *, row_block=8192):
    """Regularized absolute gradients ĝ for MVS.

    Args:
      grads: float32[rows, 2] packed (g, h).
      lam: float32[1] regularization λ (hyperparameter, or estimated from
        the squared mean of the initial leaf value — the estimate happens
        host-side).
    Returns:
      float32[rows] sampling scores.
    """
    rows = grads.shape[0]
    assert rows % row_block == 0, (rows, row_block)
    return pl.pallas_call(
        _mvs_kernel,
        grid=(rows // row_block,),
        in_specs=[
            pl.BlockSpec((row_block, 2), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((row_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(grads, lam)
