"""Gradient-histogram Pallas kernels — the compute hot-spot of GBDT.

XGBoost's ``gpu_hist`` builds, for every tree node, a per-(feature, bin)
histogram of gradient pairs.  The CUDA implementation the paper builds on
uses shared-memory atomics per threadblock.  Neither atomics nor
shared-memory are the right primitive on a TPU, so we reformulate
(DESIGN.md §Hardware-Adaptation):

* ``build_histogram_scatter`` — the *deployment* kernel: one scatter-add
  per (row, feature) into a flattened ``[nodes * features * bins]`` table.
  Lowered under ``interpret=True`` this becomes a plain HLO scatter, which
  the XLA *CPU* backend executes in O(rows · features) — this is what the
  Rust runtime actually runs.

* ``build_histogram_onehot`` — the *MXU* formulation: the bin lookup is
  expressed as ``one_hot(bins)ᵀ · grads`` so a real TPU would feed the
  128×128 systolic array with a dense matmul instead of scattering.  It is
  numerically identical (tested against the scatter kernel and ``ref.py``)
  and is what we would ship for TPU hardware; we keep tiles small enough
  that the one-hot block fits VMEM.

Both kernels tile rows with a Pallas grid: the row dimension is split into
``row_block`` chunks streamed HBM→VMEM by ``BlockSpec``, while the output
histogram stays resident in VMEM across grid steps (the classic
revisited-output accumulation pattern; this is the Pallas analogue of the
paper's CUDA persistent-histogram-in-shared-memory).

Conventions shared with the Rust coordinator (rust/src/runtime):

* ``bins``:  ``int32[rows, features]`` quantized feature matrix (ELLPACK
  page contents), values in ``[0, n_bins)``.
* ``grads``: ``float32[rows, 2]`` — ``(g_i, h_i)`` pairs.  **Padding rows
  must carry zero gradients**; they may point at any (node, bin) and still
  contribute exactly nothing, which is why Rust-side padding is exact.
* ``node_ids``: ``int32[rows]`` in ``[0, n_nodes)`` — the tree-level node
  each row currently sits in (level-wise construction builds one whole
  tree level per data pass).
* output: ``float32[n_nodes, features, n_bins, 2]``.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_scatter_kernel(bins_ref, grads_ref, nodes_ref, out_ref, *, n_nodes,
                         n_features, n_bins):
    """One grid step: scatter-add a row block into the resident histogram."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[...]  # [RB, F] int32
    grads = grads_ref[...]  # [RB, 2] f32
    nodes = nodes_ref[...]  # [RB] int32

    rb, f = bins.shape
    # Flattened destination index for every (row, feature) update:
    #   idx = (node * F + feature) * NB + bin
    feat = jax.lax.broadcasted_iota(jnp.int32, (rb, f), 1)
    idx = (nodes[:, None] * n_features + feat) * n_bins + bins  # [RB, F]
    upd = jnp.broadcast_to(grads[:, None, :], (rb, f, 2))  # [RB, F, 2]

    flat = out_ref[...].reshape(n_nodes * n_features * n_bins, 2)
    flat = flat.at[idx.reshape(-1)].add(upd.reshape(-1, 2))
    out_ref[...] = flat.reshape(out_ref.shape)


def build_histogram_scatter(bins, grads, node_ids, *, n_nodes, n_bins,
                            row_block=4096):
    """Level-wise gradient histogram via Pallas scatter-add.

    Args:
      bins: int32[rows, features], quantized features.
      grads: float32[rows, 2], (g, h) pairs; zero rows are inert padding.
      node_ids: int32[rows], node slot per row in [0, n_nodes).
      n_nodes: number of node slots in this level chunk.
      n_bins: quantization width (max_bin).
      row_block: rows per grid step (VMEM tile height).

    Returns:
      float32[n_nodes, features, n_bins, 2].
    """
    rows, features = bins.shape
    assert rows % row_block == 0, (rows, row_block)
    grid = rows // row_block
    kernel = partial(_hist_scatter_kernel, n_nodes=n_nodes,
                     n_features=features, n_bins=n_bins)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((row_block, features), lambda i: (i, 0)),
            pl.BlockSpec((row_block, 2), lambda i: (i, 0)),
            pl.BlockSpec((row_block,), lambda i: (i,)),
        ],
        # Output block is the whole histogram, revisited by every grid step.
        out_specs=pl.BlockSpec((n_nodes, features, n_bins, 2),
                               lambda i: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_nodes, features, n_bins, 2),
                                       jnp.float32),
        interpret=True,
    )(bins, grads, node_ids)


def _hist_onehot_kernel(bins_ref, grads_ref, nodes_ref, out_ref, *, n_nodes,
                        n_bins):
    """MXU formulation: one-hot(node⊗bin) matmul per feature column.

    For each feature f the update is
        out[:, f, :, k] += one_hot(node*NB + bin_f)ᵀ · grads[:, k]
    i.e. a ``[NN*NB, RB] × [RB, 2]`` matmul — systolic-array food.  The
    feature loop is unrolled by the grid's second axis.
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[...]  # [RB, 1] int32 (single feature column)
    grads = grads_ref[...]  # [RB, 2] f32
    nodes = nodes_ref[...]  # [RB] int32

    rb = grads.shape[0]
    slot = nodes * n_bins + bins[:, 0]  # [RB]
    oh = (slot[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (rb, n_nodes * n_bins), 1)).astype(jnp.float32)
    # [NN*NB, RB] @ [RB, 2] -> [NN*NB, 2]
    acc = jnp.dot(oh.T, grads, preferred_element_type=jnp.float32)
    out_ref[...] = out_ref[...] + acc.reshape(n_nodes, 1, n_bins, 2)


def build_histogram_onehot(bins, grads, node_ids, *, n_nodes, n_bins,
                           row_block=1024):
    """Same contract as :func:`build_histogram_scatter`, MXU-shaped.

    VMEM model per grid step (f32): one-hot block ``RB × NN·NB`` plus the
    feature's histogram slab ``NN·NB × 2``.  With RB=1024, NN=32, NB=64 the
    one-hot block is 1024×2048×4 B = 8 MiB — inside a 16 MiB VMEM budget.
    """
    rows, features = bins.shape
    assert rows % row_block == 0, (rows, row_block)
    grid = (rows // row_block, features)
    kernel = partial(_hist_onehot_kernel, n_nodes=n_nodes, n_bins=n_bins)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, 1), lambda i, j: (i, j)),
            pl.BlockSpec((row_block, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((n_nodes, 1, n_bins, 2),
                               lambda i, j: (0, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_nodes, features, n_bins, 2),
                                       jnp.float32),
        interpret=True,
    )(bins, grads, node_ids)
