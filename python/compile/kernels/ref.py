"""Pure-jnp/numpy reference oracles for every L1 kernel and L2 graph.

These are deliberately written in the most obvious way possible (explicit
loops where that is clearest) — they are the correctness ground truth that
pytest/hypothesis compare the Pallas kernels and the lowered HLO against.
They are never lowered or shipped.
"""

import numpy as np


def histogram_ref(bins, grads, node_ids, n_nodes, n_bins):
    """O(rows·features) loop-free numpy histogram — the ground truth.

    Args/shapes match ``kernels.histogram``: bins int[rows, F],
    grads f32[rows, 2], node_ids int[rows] → f32[n_nodes, F, n_bins, 2].
    """
    rows, features = bins.shape
    out = np.zeros((n_nodes, features, n_bins, 2), dtype=np.float64)
    feat = np.broadcast_to(np.arange(features)[None, :], (rows, features))
    nid = np.broadcast_to(np.asarray(node_ids)[:, None], (rows, features))
    flat = (nid * features + feat) * n_bins + np.asarray(bins)
    upd = np.broadcast_to(np.asarray(grads)[:, None, :], (rows, features, 2))
    np.add.at(out.reshape(-1, 2), flat.reshape(-1), upd.reshape(-1, 2))
    return out.reshape(n_nodes, features, n_bins, 2).astype(np.float32)


def logistic_gradients_ref(preds, labels):
    p = 1.0 / (1.0 + np.exp(-np.asarray(preds, dtype=np.float64)))
    g = p - np.asarray(labels, dtype=np.float64)
    h = np.maximum(p * (1.0 - p), 1e-16)
    return np.stack([g, h], axis=-1).astype(np.float32)


def squared_gradients_ref(preds, labels):
    g = np.asarray(preds, dtype=np.float64) - np.asarray(labels,
                                                         dtype=np.float64)
    return np.stack([g, np.ones_like(g)], axis=-1).astype(np.float32)


def mvs_scores_ref(grads, lam):
    g = np.asarray(grads, dtype=np.float64)
    return np.sqrt(g[:, 0] ** 2 + float(lam) * g[:, 1] ** 2).astype(
        np.float32)


def evaluate_splits_ref(hist, lam, gamma, min_child_weight):
    """Best split per node from its histogram (paper Eq. 8), numpy loops.

    Args:
      hist: f32[n_nodes, F, n_bins, 2].
      lam, gamma, min_child_weight: floats (XGBoost λ, γ, min hessian sum).
    Returns dict of arrays (all length n_nodes):
      gain f32, feature i32, split_bin i32, left_sum f32[,2], total f32[,2].
      feature == -1 when no split improves the loss.
    A split at bin b sends rows with ``bin <= b`` left.
    """
    hist = np.asarray(hist, dtype=np.float64)
    n_nodes, features, n_bins, _ = hist.shape
    gain = np.zeros(n_nodes, dtype=np.float64)
    best_f = np.full(n_nodes, -1, dtype=np.int32)
    best_b = np.full(n_nodes, -1, dtype=np.int32)
    left_sum = np.zeros((n_nodes, 2), dtype=np.float64)
    total = np.zeros((n_nodes, 2), dtype=np.float64)
    for n in range(n_nodes):
        tot = hist[n, 0].sum(axis=0)  # total (g,h) is same for every feature
        total[n] = tot
        parent = tot[0] ** 2 / (tot[1] + lam)
        for f in range(features):
            gl, hl = 0.0, 0.0
            for b in range(n_bins - 1):  # last bin left = no split
                gl += hist[n, f, b, 0]
                hl += hist[n, f, b, 1]
                gr, hr = tot[0] - gl, tot[1] - hl
                if hl < min_child_weight or hr < min_child_weight:
                    continue
                g_split = 0.5 * (gl ** 2 / (hl + lam) + gr ** 2 / (hr + lam)
                                 - parent) - gamma
                if g_split > gain[n] + 1e-12:
                    gain[n] = g_split
                    best_f[n] = f
                    best_b[n] = b
                    left_sum[n] = (gl, hl)
    return {
        "gain": gain.astype(np.float32),
        "feature": best_f,
        "split_bin": best_b,
        "left_sum": left_sum.astype(np.float32),
        "total": total.astype(np.float32),
    }
