"""Elementwise gradient-pair Pallas kernels.

Each boosting iteration starts by computing first/second-order gradients of
the loss at the current margin (paper Eq. 5).  These are elementwise over
rows, so the kernels are simple VPU (vector-unit) tiles: rows stream
HBM→VMEM in ``row_block`` chunks, one fused multiply-add chain per element.

Outputs are packed ``float32[rows, 2]`` as ``(g, h)`` — the exact layout the
histogram kernels and the Rust coordinator consume.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _logistic_kernel(preds_ref, labels_ref, out_ref):
    """binary:logistic — g = σ(margin) − y,  h = σ(margin)(1 − σ(margin))."""
    margin = preds_ref[...]
    y = labels_ref[...]
    p = jax.nn.sigmoid(margin)
    g = p - y
    h = jnp.maximum(p * (1.0 - p), 1e-16)  # XGBoost clamps the hessian
    out_ref[...] = jnp.stack([g, h], axis=-1)


def _squared_kernel(preds_ref, labels_ref, out_ref):
    """reg:squarederror — g = pred − y,  h = 1."""
    pred = preds_ref[...]
    y = labels_ref[...]
    out_ref[...] = jnp.stack([pred - y, jnp.ones_like(pred)], axis=-1)


def _elementwise_call(kernel, preds, labels, row_block):
    rows, = preds.shape
    assert rows % row_block == 0, (rows, row_block)
    return pl.pallas_call(
        kernel,
        grid=(rows // row_block,),
        in_specs=[
            pl.BlockSpec((row_block,), lambda i: (i,)),
            pl.BlockSpec((row_block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((row_block, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 2), jnp.float32),
        interpret=True,
    )(preds, labels)


def logistic_gradients(preds, labels, *, row_block=8192):
    """Gradient pairs for binary logistic loss.

    Args:
      preds: float32[rows] raw margins (pre-sigmoid).
      labels: float32[rows] in {0, 1}.
    Returns:
      float32[rows, 2] packed (g, h).
    """
    return _elementwise_call(_logistic_kernel, preds, labels, row_block)


def squared_gradients(preds, labels, *, row_block=8192):
    """Gradient pairs for squared-error regression."""
    return _elementwise_call(_squared_kernel, preds, labels, row_block)
