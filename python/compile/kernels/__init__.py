"""Layer-1 Pallas kernels for out-of-core gradient boosting.

Every kernel here is authored with ``jax.experimental.pallas`` and lowered
with ``interpret=True`` so the resulting HLO runs on any PJRT backend
(including the Rust-driven CPU client).  On a real TPU the same kernels
would lower to Mosaic; the BlockSpec tiling below is written against a
16 MiB VMEM budget (see DESIGN.md §Hardware-Adaptation).
"""

from .histogram import (  # noqa: F401
    build_histogram_scatter,
    build_histogram_onehot,
)
from .gradients import logistic_gradients, squared_gradients  # noqa: F401
from .mvs import mvs_scores  # noqa: F401
