"""Layer-2 JAX compute graphs for out-of-core gradient boosting.

These are the functions that get AOT-lowered (``aot.py``) to HLO text and
executed from the Rust coordinator via PJRT.  Each one composes the L1
Pallas kernels with whatever surrounding jnp math the step needs, so the
kernel and its glue fuse into a single XLA module — one device dispatch per
logical step on the Rust hot path.

Graphs
------
* ``histogram_step``     — level-wise gradient histogram (Alg. 1/7 inner loop)
* ``gradient_step``      — loss gradients for an objective
* ``mvs_step``           — MVS sampling scores + their sum (Eq. 9)
* ``evaluate_splits``    — best split per node from histograms (Eq. 8)

Shape discipline: everything is fixed-shape (HLO requirement).  The Rust
runtime pads the tail batch with zero-gradient rows (exactly inert for
histograms/gradients, see kernels/histogram.py) and slices the outputs.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    build_histogram_scatter,
    logistic_gradients,
    squared_gradients,
    mvs_scores,
)


def histogram_step(bins, grads, node_ids, *, n_nodes, n_bins,
                   row_block=4096):
    """Build the gradient histogram for one batch of rows.

    Returns f32[n_nodes, features, n_bins, 2]; the Rust side accumulates
    across batches (fp32 add, order-independent across pages up to fp
    rounding; EXPERIMENTS.md quantifies the tolerance).
    """
    return (build_histogram_scatter(bins, grads, node_ids, n_nodes=n_nodes,
                                    n_bins=n_bins, row_block=row_block),)


def gradient_step(preds, labels, *, objective):
    """Gradient pairs for one batch of rows under the given objective."""
    if objective == "binary:logistic":
        return (logistic_gradients(preds, labels),)
    if objective == "reg:squarederror":
        return (squared_gradients(preds, labels),)
    raise ValueError(f"unknown objective: {objective}")


def mvs_step(grads, lam):
    """MVS scores ĝ plus their sum (the host threshold search needs Σĝ)."""
    scores = mvs_scores(grads, lam)
    return (scores, jnp.sum(scores, dtype=jnp.float32))


def evaluate_splits(hist, params):
    """Best split per node from its histogram — vectorized Eq. 8.

    Args:
      hist: f32[n_nodes, F, n_bins, 2] accumulated gradient histograms.
      params: f32[3] = (λ, γ, min_child_weight).

    Returns (all per node):
      gain f32[N], feature i32[N] (−1 = leaf), split_bin i32[N],
      left_sum f32[N, 2], total f32[N, 2].

    Split semantics: rows with ``bin <= split_bin`` go left.  The scan over
    candidate bins is a cumulative sum along the bin axis; the final bin is
    excluded (it would send everything left).  Ties resolve to the lowest
    (feature, bin) — matching the Rust CPU evaluator bit-for-bit is tested
    in rust/tests/.
    """
    lam, gamma, min_child_weight = params[0], params[1], params[2]
    # Totals are identical across features; use feature 0.
    total = jnp.sum(hist[:, 0, :, :], axis=1)  # [N, 2]
    parent = total[:, 0] ** 2 / (total[:, 1] + lam)  # [N]

    cum = jnp.cumsum(hist, axis=2)  # [N, F, B, 2]
    gl, hl = cum[..., 0], cum[..., 1]  # [N, F, B]
    gr = total[:, None, None, 0] - gl
    hr = total[:, None, None, 1] - hl

    gain = 0.5 * (gl ** 2 / (hl + lam) + gr ** 2 / (hr + lam)
                  - parent[:, None, None]) - gamma  # [N, F, B]
    valid = (hl >= min_child_weight) & (hr >= min_child_weight)
    # Exclude the last bin (no-op split).
    n_bins = hist.shape[2]
    bin_idx = jax.lax.broadcasted_iota(jnp.int32, gain.shape, 2)
    valid = valid & (bin_idx < n_bins - 1)
    gain = jnp.where(valid, gain, -jnp.inf)

    flat = gain.reshape(gain.shape[0], -1)  # [N, F*B]
    best = jnp.argmax(flat, axis=1).astype(jnp.int32)  # first max wins
    best_gain = jnp.take_along_axis(flat, best[:, None], axis=1)[:, 0]
    has_split = best_gain > 0.0
    feature = jnp.where(has_split, best // n_bins, -1).astype(jnp.int32)
    split_bin = jnp.where(has_split, best % n_bins, -1).astype(jnp.int32)

    nf = hist.shape[1]
    safe_f = jnp.clip(feature, 0, nf - 1)
    safe_b = jnp.clip(split_bin, 0, n_bins - 1)
    left = cum[jnp.arange(hist.shape[0]), safe_f, safe_b, :]  # [N, 2]
    left = jnp.where(has_split[:, None], left, 0.0)
    best_gain = jnp.where(has_split, best_gain, 0.0)
    return (best_gain.astype(jnp.float32), feature, split_bin,
            left.astype(jnp.float32), total.astype(jnp.float32))
