"""AOT compiler: lower every L2 graph to HLO *text* + a manifest.

Run once at build time (``make artifacts``); the Rust runtime
(rust/src/runtime) loads the emitted ``*.hlo.txt`` via
``HloModuleProto::from_text_file`` and executes them on the PJRT CPU
client.  Python is never on the request path.

Why HLO text and not ``lowered.compile().serialize()``: the image's
xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit instruction
ids, ``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Every artifact is listed in ``artifacts/manifest.json`` with its kind,
static parameters and I/O signature; the Rust side is entirely
manifest-driven (no shape constants duplicated in Rust).

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Compiled shape variants.  B = row batch; the Rust runtime pads the tail
# batch with zero-gradient rows (exact, see kernels/histogram.py) and picks
# the largest variant <= the work size, so both a small variant (tests,
# tiny datasets) and a big one (bench workloads) are emitted.
HIST_BATCHES = (4096, 16384)
GRAD_BATCHES = (8192, 65536)
N_NODES = 32     # node slots per histogram/eval call (level chunking)
F_TILE = 32      # feature tile width
N_BINS = 64      # max_bin (paper default 256; 64 keeps the CPU-backend
                 # runtime practical — ablation artifact uses 256)
N_BINS_ABLATION = 256


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(specs):
    return [{"dtype": str(s.dtype), "shape": list(s.shape)} for s in specs]


def build_artifacts():
    """Yield (name, kind, params, fn, input_specs) for every artifact."""
    for b in HIST_BATCHES:
        for nb in (N_BINS, N_BINS_ABLATION):
            name = f"hist_b{b}_f{F_TILE}_n{N_NODES}_bin{nb}"
            # row_block = min(b, 8192): fewer grid steps per call beat
            # smaller VMEM tiles on this backend (§Perf L1 iteration 3);
            # 8192×32×4 B = 1 MiB block + the 0.5 MiB histogram stays
            # far inside the 16 MiB VMEM model.
            fn = partial(model.histogram_step, n_nodes=N_NODES, n_bins=nb,
                         row_block=min(b, 8192))
            specs = (
                _spec((b, F_TILE), jnp.int32),   # bins
                _spec((b, 2), jnp.float32),      # grads
                _spec((b,), jnp.int32),          # node ids
            )
            yield (name, "histogram",
                   {"batch": b, "features": F_TILE, "nodes": N_NODES,
                    "bins": nb}, fn, specs)

    for b in GRAD_BATCHES:
        for obj, tag in (("binary:logistic", "logistic"),
                         ("reg:squarederror", "squared")):
            name = f"grad_{tag}_b{b}"
            fn = partial(model.gradient_step, objective=obj)
            specs = (_spec((b,), jnp.float32), _spec((b,), jnp.float32))
            yield (name, "gradient", {"batch": b, "objective": obj}, fn,
                   specs)

    for b in GRAD_BATCHES:
        name = f"mvs_b{b}"
        specs = (_spec((b, 2), jnp.float32), _spec((1,), jnp.float32))
        yield (name, "mvs", {"batch": b}, model.mvs_step, specs)

    for nb in (N_BINS, N_BINS_ABLATION):
        name = f"eval_splits_n{N_NODES}_f{F_TILE}_bin{nb}"
        specs = (
            _spec((N_NODES, F_TILE, nb, 2), jnp.float32),  # hist
            _spec((3,), jnp.float32),                      # λ, γ, mcw
        )
        yield (name, "eval_splits",
               {"nodes": N_NODES, "features": F_TILE, "bins": nb},
               model.evaluate_splits, specs)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": 1, "artifacts": []}
    for name, kind, params, fn, specs in build_artifacts():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        outs = lowered.out_info
        out_sig = [{"dtype": str(o.dtype), "shape": list(o.shape)}
                   for o in jax.tree_util.tree_leaves(outs)]
        manifest["artifacts"].append({
            "name": name,
            "file": fname,
            "kind": kind,
            "params": params,
            "inputs": _sig(specs),
            "outputs": out_sig,
        })
        print(f"  {fname}  ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json "
          f"to {args.out_dir}")


if __name__ == "__main__":
    main()
