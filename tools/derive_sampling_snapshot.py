#!/usr/bin/env python3
"""Derive `benches/BENCH_sampling.json` without a Rust toolchain.

This is the Python twin of `bench_ablations` arm 10
(`ablate_sampling_skip`): it replays the exact xoshiro256** Bernoulli
masks (`rust/src/util/rng.rs`), folds them into per-page sample bitmaps
over the pinned 8-pages x 64-rows layout, and reproduces the page-store
frame arithmetic for both codecs (`rust/src/page/store.rs`,
`rust/src/page/bitpack.rs`), so the JSON it writes matches the bench's
emitted `BENCH {"bench": "sampling_skip", ...}` line field-for-field
(every value here is an exact integer).

Usage:
    python3 tools/derive_sampling_snapshot.py          # rewrite snapshot
    python3 tools/derive_sampling_snapshot.py --print  # stdout only
"""

import json
import sys
from pathlib import Path

MASK64 = (1 << 64) - 1

# ---- RNG: splitmix64-seeded xoshiro256** (rust/src/util/rng.rs) ----


def _splitmix64(state):
    state = (state + 0x9E37_79B9_7F4A_7C15) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK64
    return state, z ^ (z >> 31)


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK64


class Rng:
    def __init__(self, seed):
        s = []
        for _ in range(4):
            seed, v = _splitmix64(seed)
            s.append(v)
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_f64(self):
        # Exact: a <= 53-bit integer times 2^-53.
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def bernoulli(self, p):
        return self.next_f64() < p


# ---- pinned shape (keep in lockstep with ablate_sampling_skip) ----

N_PAGES = 8
ROWS_PER_PAGE = 64
STRIDE = 8
BINS = 64
N_SYMBOLS = STRIDE * BINS + 1
N_ROWS = N_PAGES * ROWS_PER_PAGE
RATIOS_PCT = (10, 50)
MASK_SEED_BASE = 2020


def page_symbols(p):
    """ELLPACK symbols of pinned page `p`: sym(r, k) = k*64 + (r+p) % 64."""
    return [
        [k * BINS + (r + p) % BINS for k in range(STRIDE)]
        for r in range(ROWS_PER_PAGE)
    ]


def raw_frame_bytes():
    """Page-store frame of a raw ELLPACK page: 1 codec byte + the
    48-byte page header + ceil(rows*stride*bits/64) packed words, where
    bits = bit_length(n_symbols - 1) (rust/src/ellpack/page.rs)."""
    bits = (N_SYMBOLS - 1).bit_length()
    n_words = (ROWS_PER_PAGE * STRIDE * bits + 63) // 64
    return 1 + 48 + n_words * 8


def bitpack_frame_bytes(p):
    """Page-store frame of a bit-packed page (rust/src/page/bitpack.rs):
    1 codec byte + 48-byte header + n_runs x 16 (RLE of effective row
    lengths) + stride x 6 (column headers: min u32, width u8, has_null
    u8) + 8 (word count) + column-major packed words.  The pinned pages
    are dense, so every row's effective length is the full stride (one
    run) and no column has nulls."""
    syms = page_symbols(p)
    runs = 1  # all rows share effective length == STRIDE
    total_bits = 0
    for k in range(STRIDE):
        col = [syms[r][k] for r in range(ROWS_PER_PAGE)]
        width = (max(col) - min(col)).bit_length()  # has_null = 0
        total_bits += width * len(col)
    n_words = (total_bits + 63) // 64
    return 1 + 48 + runs * 16 + STRIDE * 6 + 8 + n_words * 8


def fold(mask):
    """SampleBitmap::from_mask over the pinned page layout → per-arm
    counters (one filtered sweep of all pages)."""
    live = [
        any(mask[p * ROWS_PER_PAGE : (p + 1) * ROWS_PER_PAGE])
        for p in range(N_PAGES)
    ]
    pages_read = sum(live)
    pages_skipped = N_PAGES - pages_read
    return pages_read, pages_skipped, pages_skipped * ROWS_PER_PAGE


def main():
    raw_frame = raw_frame_bytes()
    bp_frames = {bitpack_frame_bytes(p) for p in range(N_PAGES)}
    assert len(bp_frames) == 1, "pinned pages must share a frame size"
    bp_frame = bp_frames.pop()
    assert bp_frame < raw_frame, (bp_frame, raw_frame)

    arms = {}
    for pct in RATIOS_PCT:
        rng = Rng(MASK_SEED_BASE + pct)
        ratio = pct / 100.0
        uniform = [rng.bernoulli(ratio) for _ in range(N_ROWS)]
        n_sel = sum(uniform)
        packed = [i < n_sel for i in range(N_ROWS)]
        skipped_by_layout = []
        for layout, mask in (("uniform", uniform), ("stratified", packed)):
            read, skipped, rows_skipped = fold(mask)
            skipped_by_layout.append(skipped)
            arms[f"ratio{pct}_{layout}"] = {
                "n_selected": n_sel,
                "pages_read": read,
                "pages_skipped": skipped,
                "rows_skipped": rows_skipped,
                "raw_bytes_read": read * raw_frame,
                "raw_bytes_avoided": skipped * raw_frame,
                "bitpack_bytes_read": read * bp_frame,
                "bitpack_bytes_avoided": skipped * bp_frame,
            }
        assert skipped_by_layout[1] >= skipped_by_layout[0], pct
        assert skipped_by_layout[1] > 0, pct

    snap = {
        "bench": "sampling_skip",
        "note": (
            "Deterministic page-skip snapshot: Bernoulli masks "
            "(xoshiro256** seed 2020+pct) folded into per-page sample "
            "bitmaps over a pinned 8-pages x 64-rows x 8-features x "
            "64-bins layout, with page-store frame sizes for both codecs "
            "derived from the wire formats. Uniform = mask over spill "
            "order; stratified = the same selection count packed into "
            "the leading pages. Regenerate with `python3 "
            "tools/derive_sampling_snapshot.py` or from the BENCH line "
            "of `cargo bench --bench bench_ablations` (arm 10)."
        ),
        "shape": {
            "n_pages": N_PAGES,
            "rows_per_page": ROWS_PER_PAGE,
            "features": STRIDE,
            "bins_per_feature": BINS,
        },
        "raw_frame_bytes": raw_frame,
        "bitpack_frame_bytes": bp_frame,
        "arms": arms,
    }

    text = json.dumps(snap, indent=2) + "\n"
    if "--print" in sys.argv[1:]:
        sys.stdout.write(text)
        return
    out = Path(__file__).resolve().parent.parent / "benches" / "BENCH_sampling.json"
    out.write_text(text)
    skips = {k: v["pages_skipped"] for k, v in arms.items()}
    print(f"wrote {out} (frames raw={raw_frame} bitpack={bp_frame}, skips {skips})")


if __name__ == "__main__":
    main()
