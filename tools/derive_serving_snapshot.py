#!/usr/bin/env python3
"""Derive `benches/BENCH_serving.json` without a Rust toolchain.

This is the Python twin of `bench_ablations` arm 9 (`ablate_serving`):
it replays the exact same xoshiro256** stream (`rust/src/util/rng.rs`),
builds the same pinned synthetic forest and request batch, runs the same
node-visit census, and applies the same cache cost + batching latency
model, so the JSON it writes matches the bench's emitted `BENCH
{"bench": "serving", ...}` line field-for-field (ints exactly, floats
well inside `check_bench_snapshots.py`'s 1e-6 relative tolerance).

Usage:
    python3 tools/derive_serving_snapshot.py          # rewrite snapshot
    python3 tools/derive_serving_snapshot.py --print  # stdout only
"""

import json
import math
import sys
from pathlib import Path

MASK64 = (1 << 64) - 1

# ---- RNG: splitmix64-seeded xoshiro256** (rust/src/util/rng.rs) ----


def _splitmix64(state):
    state = (state + 0x9E37_79B9_7F4A_7C15) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK64
    return state, z ^ (z >> 31)


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK64


class Rng:
    def __init__(self, seed):
        s = []
        for _ in range(4):
            seed, v = _splitmix64(seed)
            s.append(v)
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK64, 7) * 9) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_f64(self):
        # Exact: a <= 53-bit integer times 2^-53.
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def gen_range(self, n):
        # Lemire's unbiased method, bit-for-bit (u128 product in Rust is
        # exact big-int arithmetic here).
        x = self.next_u64()
        m = x * n
        l = m & MASK64
        if l < n:
            t = ((1 << 64) - n) % n
            while l < t:
                x = self.next_u64()
                m = x * n
                l = m & MASK64
        return m >> 64


# ---- pinned shape (keep in lockstep with ablate_serving) ----

N_FEATURES = 50
BINS = 64
N_TREES = 100
TREE_DEPTH = 6
NODES_PER_TREE = (1 << (TREE_DEPTH + 1)) - 1
ROWS = 2048
NULL_DENOM = 66
NULL_SYMBOL = N_FEATURES * BINS

MISS_NS = 80.0
HIT_NS = 4.0
DENSIFY_NS = 50.0
ARRIVAL_US = 5.0
DEADLINE_US = 2000.0


def build_forest(rng):
    """Preorder perfect trees; RNG order: interior f then bin, leaf weight.

    Returns per-tree parallel arrays (gthr, feature, left, right); leaves
    carry gthr = -1.  Node ids are tree-local (the census stamps per
    (block, tree) pair, so global ids are unnecessary).
    """
    trees = []
    for _ in range(N_TREES):
        gthr, feat, left, right = [], [], [], []

        def grow(depth):
            idx = len(gthr)
            if depth == TREE_DEPTH:
                rng.next_f64()  # leaf weight draw (value unused here)
                gthr.append(-1)
                feat.append(-1)
                left.append(0)
                right.append(0)
                return idx
            f = rng.gen_range(N_FEATURES)
            b = rng.gen_range(BINS)
            gthr.append(f * BINS + b)
            feat.append(f)
            left.append(0)
            right.append(0)
            l = grow(depth + 1)
            r = grow(depth + 1)
            left[idx] = l
            right[idx] = r
            return idx

        grow(0)
        assert len(gthr) == NODES_PER_TREE
        trees.append((gthr, feat, left, right))
    return trees


def build_batch(rng):
    rows = []
    for _ in range(ROWS):
        row = []
        for f in range(N_FEATURES):
            r = rng.gen_range(NULL_DENOM)
            row.append(NULL_SYMBOL if r >= BINS else f * BINS + r)
        rows.append(row)
    return rows


def walk(tree, row, visit):
    gthr, feat, left, right = tree
    i = 0
    while True:
        visit(i)
        if gthr[i] < 0:
            return
        sym = row[feat[i]]
        i = left[i] if (sym == NULL_SYMBOL or sym <= gthr[i]) else right[i]


def census_cold(trees, rows, block):
    """Distinct nodes touched per (row-block, tree) — compiled cold loads."""
    cold = 0
    b = 0
    while b < ROWS:
        n = min(ROWS - b, block)
        for tree in trees:
            seen = set()
            for row in rows[b : b + n]:
                walk(tree, row, seen.add)
            cold += len(seen)
        b += n
    return cold


def nearest_rank(sorted_v, p):
    n = len(sorted_v)
    rank = math.ceil(p / 100.0 * n)
    return sorted_v[min(max(rank, 1), n) - 1]


def main():
    rng = Rng(2027)
    trees = build_forest(rng)
    rows = build_batch(rng)

    visits_per_row = N_TREES * (TREE_DEPTH + 1)
    total = [0]
    for row in rows:
        for tree in trees:
            walk(tree, row, lambda _i: total.__setitem__(0, total[0] + 1))
    assert total[0] == ROWS * visits_per_row

    cold = {blk: census_cold(trees, rows, blk) for blk in (1, 8, 64)}
    assert cold[1] == total[0], "blocks of 1 must make every visit cold"
    assert cold[64] < cold[8] < cold[1]

    naive_row_ns = visits_per_row * MISS_NS + DENSIFY_NS

    def compiled_row_ns(c):
        miss_pr = c / ROWS
        return miss_pr * MISS_NS + (visits_per_row - miss_pr) * HIT_NS

    speedup = naive_row_ns / compiled_row_ns(cold[64])
    assert speedup >= 1.0

    arms = []
    for batch in (1, 8, 64, 256):
        blk = batch if batch in (1, 8) else 64
        n_fill = min(batch, int(DEADLINE_US / ARRIVAL_US) + 1)
        per_batch = {}
        for layout in ("naive", "compiled"):
            per_row_ns = naive_row_ns if layout == "naive" else compiled_row_ns(cold[blk])
            service_us = n_fill * per_row_ns / 1e3
            lats = sorted(
                (n_fill - 1 - i) * ARRIVAL_US + service_us for i in range(n_fill)
            )
            rows_per_sec = 1e9 / per_row_ns
            per_batch[layout] = rows_per_sec
            arms.append(
                {
                    "batch": batch,
                    "layout": layout,
                    "rows_per_sec": rows_per_sec,
                    "p50_us": nearest_rank(lats, 50.0),
                    "p99_us": nearest_rank(lats, 99.0),
                }
            )
        assert per_batch["compiled"] > per_batch["naive"]

    snap = {
        "bench": "serving",
        "note": (
            "Deterministic serving snapshot: node-visit census over a pinned "
            "synthetic forest (100 perfect depth-6 trees, 50 features x 64 "
            "bins, 2048 rows, xoshiro256** seed 2027) feeding a cache cost "
            "model (miss/hit/densify ns constants below) and a 5us-arrival "
            "batching latency model. Regenerate with "
            "`python3 tools/derive_serving_snapshot.py` or from the BENCH "
            "line of `cargo bench --bench bench_ablations` (arm 9)."
        ),
        "shape": {
            "n_trees": N_TREES,
            "tree_depth": TREE_DEPTH,
            "nodes_per_tree": NODES_PER_TREE,
            "n_features": N_FEATURES,
            "bins_per_feature": BINS,
            "rows": ROWS,
            "null_rate_denom": NULL_DENOM,
        },
        "visits_per_row": visits_per_row,
        "census": {
            "cold_block1": cold[1],
            "cold_block8": cold[8],
            "cold_block64": cold[64],
        },
        "model_ns": {
            "miss": MISS_NS,
            "hit": HIT_NS,
            "densify_naive": DENSIFY_NS,
        },
        "arms": arms,
        "speedup": speedup,
    }

    text = json.dumps(snap, indent=2) + "\n"
    if "--print" in sys.argv[1:]:
        sys.stdout.write(text)
        return
    out = Path(__file__).resolve().parent.parent / "benches" / "BENCH_serving.json"
    out.write_text(text)
    print(f"wrote {out} (speedup {speedup:.2f}x, cold64 {cold[64]})")


if __name__ == "__main__":
    main()
