#!/usr/bin/env python3
"""Derive `benches/BENCH_distributed.json` without a Rust toolchain.

This is the Python twin of `bench_ablations` arm 11
(`ablate_comm_backend`): it reproduces, from the wire format alone, the
byte counters each communicator backend accumulates while running the
pinned schedule — ALLREDUCES exact fixed-point allreduces of HIST_LEN
i64 lanes plus one BCAST_BYTES broadcast, at n_shards in {1, 2, 4}.

Every number is exact integer arithmetic over the frame layout in
`rust/src/comm/frame.rs` (28-byte header: magic u32, version u16, kind
u16, seq u64, payload_len u32, fnv64 u64) and the payload encodings in
`rust/src/comm/wire.rs` (i64 vectors are a u32 count + 8 bytes per
lane), mirroring the counter call sites:

* ``local`` (`comm/local.rs`) — the in-process merge never touches the
  byte counters: zero sent, zero recv, one round per completed
  allreduce.
* ``threaded`` (`comm/threaded.rs`) — each rank counts its contributed
  partial as sent (8·HIST_LEN) and the reduction it reads back as recv
  (8·HIST_LEN); the broadcast root counts the payload as sent once and
  each of the other n−1 ranks counts it as recv.  No framing — the
  fleet shares an address space.
* ``tcp`` (`comm/tcp.rs`) — head-side `FramedConn` counters: every
  frame costs 28 + payload_len in the direction it travels.  Per
  worker connection the head sends Hello (8-byte payload), one
  AllreduceRed per round, the Broadcast, and the Shutdown, and
  receives HelloAck (empty) plus one AllreducePart per round.

Usage:
    python3 tools/derive_distributed_snapshot.py          # rewrite snapshot
    python3 tools/derive_distributed_snapshot.py --print  # stdout only
"""

import json
import sys
from pathlib import Path

HIST_LEN = 256
ALLREDUCES = 3
BCAST_BYTES = 512
HEADER = 28  # comm/frame.rs HEADER_LEN
SHARD_COUNTS = (1, 2, 4)


def i64s_payload(n_lanes: int) -> int:
    """wire.rs encode_i64s: u32 count + 8 bytes per lane."""
    return 4 + 8 * n_lanes


def local_stats(n: int) -> dict:
    del n  # the in-process merge is free at every fleet size
    return {"sent": 0, "recv": 0, "rounds": ALLREDUCES}


def threaded_stats(n: int) -> dict:
    partial = 8 * HIST_LEN
    sent = ALLREDUCES * partial * n + BCAST_BYTES
    recv = ALLREDUCES * partial * n + BCAST_BYTES * (n - 1)
    return {"sent": sent, "recv": recv, "rounds": ALLREDUCES}


def tcp_stats(n: int) -> dict:
    reduce_frame = HEADER + i64s_payload(HIST_LEN)
    sent_per_conn = (
        (HEADER + 8)  # Hello: rank u32 + n_ranks u32
        + ALLREDUCES * reduce_frame  # AllreduceRed back to the worker
        + (HEADER + BCAST_BYTES)  # Broadcast
        + HEADER  # Shutdown (empty)
    )
    recv_per_conn = (
        HEADER  # HelloAck (empty)
        + ALLREDUCES * reduce_frame  # AllreducePart from the worker
    )
    return {
        "sent": sent_per_conn * n,
        "recv": recv_per_conn * n,
        "rounds": ALLREDUCES,
    }


def build() -> dict:
    sweep = []
    for n in SHARD_COUNTS:
        sweep.append(
            {
                "n_shards": n,
                "local": local_stats(n),
                "threaded": threaded_stats(n),
                "tcp": tcp_stats(n),
            }
        )
    return {
        "bench": "comm_backend",
        "hist_len": HIST_LEN,
        "allreduces": ALLREDUCES,
        "bcast_bytes": BCAST_BYTES,
        "frame_header_bytes": HEADER,
        "sweep": sweep,
    }


def main() -> None:
    snap = build()
    text = json.dumps(snap, indent=2, sort_keys=True) + "\n"
    if "--print" in sys.argv[1:]:
        sys.stdout.write(text)
        return
    out = Path(__file__).resolve().parent.parent / "benches" / "BENCH_distributed.json"
    out.write_text(text)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
