#!/usr/bin/env python3
"""Diff emitted `BENCH {json}` lines against committed snapshots.

Usage:
    cargo bench --bench bench_ablations | tee bench.out
    python3 tools/check_bench_snapshots.py bench.out [more-outputs...]

Rules enforced:

1. No committed ``benches/BENCH_*.json`` may carry ``"provisional": true``
   — snapshots must hold measured/derived numbers, never placeholders
   (and no field may be null).
2. For every snapshot whose ``bench`` name matches an emitted BENCH
   line, each snapshot field must match the emitted value: exact for
   ints/strings/bools, within a relative tolerance for floats (modeled
   seconds survive f64 accumulation-order differences; everything else
   in the snapshots is deterministic by construction).
3. A snapshot with no matching BENCH line in the provided outputs is an
   error (the bench arm was removed or renamed without updating the
   snapshot), unless no output files were given (provisional-only mode).
4. The ``serving`` snapshot additionally must be internally coherent:
   non-empty arms with known layouts, positive throughput/latency,
   ``p99 >= p50``, the compiled layout strictly beating the naive walk
   at every batch size, and an overall speedup >= 1.
5. The ``sampling_skip`` snapshot must balance its books: every page is
   either read or skipped, bytes read + bytes avoided equals the total
   for each codec (skipping never increases bytes moved), row/byte
   counts follow from page counts, the stratified layout skips at least
   as many pages as the uniform one, and some arm actually skips.
6. The ``comm_backend`` snapshot must respect the transport hierarchy:
   the local (in-process) backend moves zero bytes at every shard
   count, the threaded and tcp backends move a strictly positive and
   strictly growing number of bytes as the shard count grows, framed
   sockets cost strictly more than shared memory, and every backend
   completes the same number of allreduce rounds.

Keys named ``note`` or starting with ``_`` are documentation and are
not compared.
"""

import json
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SNAP_DIR = REPO / "benches"
REL_TOL = 1e-6
ABS_TOL = 1e-12

BENCH_LINE = re.compile(r"^BENCH (\{.*\})\s*$")


def fail(msg: str) -> None:
    print(f"error: {msg}", file=sys.stderr)
    sys.exit(1)


def is_doc_key(key: str) -> bool:
    return key == "note" or key.startswith("_")


def check_no_nulls(value, path, where):
    if value is None:
        fail(f"{where}: field {path} is null — snapshots must be fully measured")
    if isinstance(value, dict):
        for k, v in value.items():
            if not is_doc_key(k):
                check_no_nulls(v, f"{path}.{k}", where)
    if isinstance(value, list):
        for i, v in enumerate(value):
            check_no_nulls(v, f"{path}[{i}]", where)


def diff(snap, got, path, where):
    """Every non-doc snapshot field must match the emitted value."""
    if isinstance(snap, dict):
        if not isinstance(got, dict):
            fail(f"{where}: {path} is an object in the snapshot but not in the BENCH line")
        for k, v in snap.items():
            if is_doc_key(k):
                continue
            if k not in got:
                fail(f"{where}: {path}.{k} missing from the emitted BENCH line")
            diff(v, got[k], f"{path}.{k}", where)
        return
    if isinstance(snap, list):
        if not isinstance(got, list) or len(snap) != len(got):
            fail(f"{where}: {path} length/type mismatch (snapshot {snap!r} vs emitted {got!r})")
        for i, (a, b) in enumerate(zip(snap, got)):
            diff(a, b, f"{path}[{i}]", where)
        return
    if isinstance(snap, bool) or isinstance(got, bool):
        if snap is not got:
            fail(f"{where}: {path}: snapshot {snap!r} != emitted {got!r}")
        return
    if isinstance(snap, float) and not float(snap).is_integer() or (
        isinstance(got, float) and not float(got).is_integer()
    ):
        a, b = float(snap), float(got)
        if abs(a - b) > max(ABS_TOL, REL_TOL * max(abs(a), abs(b))):
            fail(f"{where}: {path}: snapshot {a!r} differs from emitted {b!r} beyond tolerance")
        return
    if isinstance(snap, (int, float)) and isinstance(got, (int, float)):
        if float(snap) != float(got):
            fail(f"{where}: {path}: snapshot {snap!r} != emitted {got!r}")
        return
    if snap != got:
        fail(f"{where}: {path}: snapshot {snap!r} != emitted {got!r}")


def check_serving(snap, where):
    """Rule 4: the serving snapshot must tell a coherent story."""
    arms = snap.get("arms")
    if not isinstance(arms, list) or not arms:
        fail(f"{where}: serving snapshot needs a non-empty \"arms\" list")
    by_batch = {}
    for i, arm in enumerate(arms):
        path = f"$.arms[{i}]"
        layout = arm.get("layout")
        if layout not in ("naive", "compiled"):
            fail(f"{where}: {path}.layout {layout!r} is not naive/compiled")
        batch = arm.get("batch")
        if not isinstance(batch, int) or batch < 1:
            fail(f"{where}: {path}.batch {batch!r} must be an int >= 1")
        for key in ("rows_per_sec", "p50_us", "p99_us"):
            v = arm.get(key)
            if not isinstance(v, (int, float)) or v <= 0:
                fail(f"{where}: {path}.{key} {v!r} must be a positive number")
        if arm["p99_us"] < arm["p50_us"]:
            fail(f"{where}: {path}: p99_us {arm['p99_us']} below p50_us {arm['p50_us']}")
        by_batch.setdefault(batch, {})[layout] = arm["rows_per_sec"]
    for batch, layouts in sorted(by_batch.items()):
        if set(layouts) != {"naive", "compiled"}:
            fail(f"{where}: batch {batch} is missing a naive or compiled arm")
        if layouts["compiled"] <= layouts["naive"]:
            fail(
                f"{where}: batch {batch}: compiled {layouts['compiled']} rows/s "
                f"does not beat naive {layouts['naive']}"
            )
    speedup = snap.get("speedup")
    if not isinstance(speedup, (int, float)) or speedup < 1.0:
        fail(f"{where}: speedup {speedup!r} must be >= 1")


def check_sampling(snap, where):
    """Rule 5: the sampling snapshot's skip accounting must be coherent —
    skipped pages can only ever *reduce* bytes moved, and the stratified
    layout must skip at least as many pages as the uniform one."""
    shape = snap.get("shape") or {}
    n_pages = shape.get("n_pages")
    rows_per_page = shape.get("rows_per_page")
    if not isinstance(n_pages, int) or n_pages < 1:
        fail(f"{where}: shape.n_pages {n_pages!r} must be an int >= 1")
    if not isinstance(rows_per_page, int) or rows_per_page < 1:
        fail(f"{where}: shape.rows_per_page {rows_per_page!r} must be an int >= 1")
    frames = {}
    for codec in ("raw", "bitpack"):
        v = snap.get(f"{codec}_frame_bytes")
        if not isinstance(v, int) or v <= 0:
            fail(f"{where}: {codec}_frame_bytes {v!r} must be a positive int")
        frames[codec] = v
    if frames["bitpack"] >= frames["raw"]:
        fail(
            f"{where}: bitpack frame {frames['bitpack']} does not beat "
            f"raw frame {frames['raw']}"
        )
    arms = snap.get("arms")
    if not isinstance(arms, dict) or not arms:
        fail(f"{where}: sampling snapshot needs a non-empty \"arms\" object")
    any_skips = False
    skipped_by_arm = {}
    for name, arm in sorted(arms.items()):
        path = f"$.arms.{name}"
        read, skipped = arm.get("pages_read"), arm.get("pages_skipped")
        for key, v in (("pages_read", read), ("pages_skipped", skipped)):
            if not isinstance(v, int) or v < 0:
                fail(f"{where}: {path}.{key} {v!r} must be an int >= 0")
        if read + skipped != n_pages:
            fail(
                f"{where}: {path}: pages_read {read} + pages_skipped {skipped} "
                f"!= n_pages {n_pages} — a page was neither read nor skipped"
            )
        if arm.get("rows_skipped") != skipped * rows_per_page:
            fail(
                f"{where}: {path}.rows_skipped {arm.get('rows_skipped')!r} "
                f"!= pages_skipped x rows_per_page ({skipped * rows_per_page})"
            )
        for codec, frame in frames.items():
            br = arm.get(f"{codec}_bytes_read")
            ba = arm.get(f"{codec}_bytes_avoided")
            if br != read * frame or ba != skipped * frame:
                fail(
                    f"{where}: {path}: {codec} byte accounting ({br!r} read, "
                    f"{ba!r} avoided) is inconsistent with {read} pages read, "
                    f"{skipped} skipped at {frame} B/frame"
                )
            if br + ba != n_pages * frame:
                fail(
                    f"{where}: {path}: {codec} read+avoided {br + ba} != total "
                    f"{n_pages * frame} — skipping may never increase bytes moved"
                )
        skipped_by_arm[name] = skipped
        any_skips = any_skips or skipped > 0
    for name, skipped in skipped_by_arm.items():
        if name.endswith("_stratified"):
            twin = name.replace("_stratified", "_uniform")
            if twin in skipped_by_arm and skipped < skipped_by_arm[twin]:
                fail(
                    f"{where}: {name} skipped {skipped} pages, fewer than "
                    f"{twin}'s {skipped_by_arm[twin]} — clustering cannot hurt"
                )
    if not any_skips:
        fail(f"{where}: no arm skipped any pages — the snapshot shows no skipping")


def check_comm(snap, where):
    """Rule 6: local is free, wire backends pay linearly in the fleet."""
    for key in ("hist_len", "allreduces", "bcast_bytes", "frame_header_bytes"):
        v = snap.get(key)
        if not isinstance(v, int) or v < 1:
            fail(f"{where}: {key} {v!r} must be an int >= 1")
    rounds_expected = snap["allreduces"]
    sweep = snap.get("sweep")
    if not isinstance(sweep, list) or not sweep:
        fail(f"{where}: comm snapshot needs a non-empty \"sweep\" list")
    prev_shards = 0
    prev_wire = {"threaded": -1, "tcp": -1}
    for i, entry in enumerate(sweep):
        path = f"$.sweep[{i}]"
        n = entry.get("n_shards")
        if not isinstance(n, int) or n <= prev_shards:
            fail(f"{where}: {path}.n_shards {n!r} must be an int > {prev_shards}")
        prev_shards = n
        moved = {}
        for backend in ("local", "threaded", "tcp"):
            arm = entry.get(backend)
            if not isinstance(arm, dict):
                fail(f"{where}: {path}.{backend} missing")
            for key in ("sent", "recv", "rounds"):
                v = arm.get(key)
                if not isinstance(v, int) or v < 0:
                    fail(f"{where}: {path}.{backend}.{key} {v!r} must be an int >= 0")
            if arm["rounds"] != rounds_expected:
                fail(
                    f"{where}: {path}.{backend}.rounds {arm['rounds']} != the "
                    f"schedule's {rounds_expected} — backends must run the same rounds"
                )
            moved[backend] = arm["sent"] + arm["recv"]
        if moved["local"] != 0:
            fail(
                f"{where}: {path}: local moved {moved['local']} bytes — the "
                f"in-process merge must be free"
            )
        for backend in ("threaded", "tcp"):
            if moved[backend] <= 0:
                fail(f"{where}: {path}.{backend} moved no bytes — not a wire transport")
            if moved[backend] <= prev_wire[backend]:
                fail(
                    f"{where}: {path}.{backend} moved {moved[backend]} bytes, not "
                    f"more than {prev_wire[backend]} at the previous shard count — "
                    f"wire bytes must grow with the fleet"
                )
            prev_wire[backend] = moved[backend]
        if moved["tcp"] <= moved["threaded"]:
            fail(
                f"{where}: {path}: tcp moved {moved['tcp']} bytes, not more than "
                f"threaded's {moved['threaded']} — framing + handshake can't be free"
            )


def main() -> None:
    snapshots = {}
    for f in sorted(SNAP_DIR.glob("BENCH_*.json")):
        snap = json.loads(f.read_text())
        where = f.relative_to(REPO)
        if snap.get("provisional"):
            fail(f"{where} is marked provisional — replace it with measured numbers")
        check_no_nulls(snap, "$", where)
        name = snap.get("bench")
        if not name:
            fail(f"{where} has no \"bench\" name field")
        if name == "serving":
            check_serving(snap, where)
        if name == "sampling_skip":
            check_sampling(snap, where)
        if name == "comm_backend":
            check_comm(snap, where)
        snapshots[name] = (snap, where)

    emitted = {}
    for arg in sys.argv[1:]:
        for line in Path(arg).read_text().splitlines():
            m = BENCH_LINE.match(line)
            if m:
                obj = json.loads(m.group(1))
                emitted[obj.get("bench")] = obj

    if sys.argv[1:]:
        for name, (snap, where) in snapshots.items():
            if name not in emitted:
                fail(f"{where}: no `BENCH` line named {name!r} in the provided bench output")
            diff(snap, emitted[name], "$", where)
            print(f"ok: {where} matches emitted bench `{name}`")
    else:
        for _, where in snapshots.values():
            print(f"ok: {where} is non-provisional and fully populated")


if __name__ == "__main__":
    main()
